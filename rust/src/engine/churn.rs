//! Pattern-churn tracking: scoring modes by pattern *lifetime*, not
//! single jobs.
//!
//! The paper's crossover result — static beats dynamic wherever both
//! apply (Table 3) — prices only execution. It holds when a sparsity
//! pattern is planned once and reused; a static plan is
//! pattern-specific, so every *fresh* pattern pays the static planning
//! cost again, while a dynamic plan amortizes one compilation across
//! every pattern under its `d_max` (the paper's headline property, and
//! the workload realism Gale et al. and the Sparsity Roofline insist
//! on measuring). A selector that scores single jobs therefore
//! systematically over-picks static under pattern churn.
//!
//! [`ChurnTracker`] closes that gap: a per-[`PatternKey`] EWMA of the
//! *distinct-pattern rate* — how often traffic at a weight geometry
//! arrives with a pattern not in its recent window. The reciprocal is
//! the expected pattern lifetime (jobs per pattern), and static's
//! per-pattern planning cost divided by that lifetime is a surcharge
//! added to static's corrected estimate before the argmin
//! ([`corrected_argmin_amortized`](crate::engine::calibration::corrected_argmin_amortized)).
//! Zero observed churn keeps the surcharge at exactly zero, so
//! pattern-stable traffic reproduces the unamortized decisions
//! bit-for-bit; as the churn rate rises the static/dynamic argmin
//! shifts toward dynamic — the `repro bench churn` sweep plots the
//! flip.
//!
//! Like [`Calibration`](crate::engine::Calibration), staleness is
//! counted in *informative movements*: an observation only advances a
//! geometry's churn stamp when it actually moved the EWMA, so memoized
//! decisions ([`PlanCache::resolve_batch`]) are revisited when the
//! workload's churn regime changes and left alone while it merely
//! continues.
//!
//! [`PlanCache::resolve_batch`]: crate::coordinator::PlanCache::resolve_batch

use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::coordinator::request::{JobSpec, PatternKey};
use crate::util::LruMap;

/// EWMA smoothing weight for distinct-pattern observations.
pub const CHURN_ALPHA: f64 = 0.25;

/// How many recently-seen distinct pattern seeds a geometry remembers
/// (LRU: reuse refreshes a seed's slot); a seed outside this window
/// counts as fresh. The window is a bounded recency horizon, not a
/// plan-cache mirror — a rotation through more than this many live
/// patterns reads as churn even where a large plan cache would still
/// serve it, which errs toward dynamic's pattern-robust plan exactly
/// when the pattern population is large.
pub const CHURN_WINDOW: usize = 8;

/// An observation is *informative* — advances the geometry's churn
/// stamp — only when it moved the EWMA by at least this much. A
/// converged stream (steady reuse or steady churn) stops advancing the
/// stamp, so memoized decisions settle once the regime settles.
pub const CHURN_INFORMATIVE_DELTA: f64 = 0.01;

/// A memoized auto-mode decision goes stale once its geometry's churn
/// EWMA has moved informatively this many times since the decision
/// was taken. Deliberately small: the EWMA saturates after ~a dozen
/// one-directional moves, so a larger threshold could leave a memo
/// frozen in the wrong regime forever.
pub const CHURN_MOVES_PER_REVISIT: u64 = 4;

/// Expected pattern lifetime is clamped to `[1, MAX_PATTERN_LIFETIME]`
/// jobs: even a fully-churning stream replans at most once per job,
/// and a near-zero rate must not divide the surcharge to nothing
/// prematurely (zero observed churn skips the surcharge entirely
/// instead).
pub const MAX_PATTERN_LIFETIME: f64 = 256.0;

/// Static's per-pattern planning cost, as a multiple of its own
/// per-batch execution estimate. On real IPUs a static pattern means
/// graph recompilation — orders of magnitude above one execution; the
/// simulator has no compile path to measure, so this documented factor
/// stands in for it. With the clamp above, pattern-stable traffic pays
/// at most `8/256 ≈ 3%` (and exactly 0 before any churn is observed),
/// while per-job-fresh patterns pay the full 8× — decisively past the
/// ~2.6× dynamic/static execution gap at the paper's block sizes, so
/// the argmin flips.
pub const STATIC_REPLAN_COST_FACTOR: f64 = 8.0;

/// Default capacity of the per-geometry churn map (entries, LRU).
pub const DEFAULT_CHURN_CAPACITY: usize = 4096;

/// Poison-tolerant lock acquisition: the churn map is self-consistent
/// at every lock release, so a panicked observer must not wedge the
/// surviving coordinator shards' resolutions.
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[derive(Debug, Clone)]
struct ChurnState {
    /// Ring of recently-seen distinct seeds, newest last.
    recent: Vec<u64>,
    /// EWMA of the fresh-pattern indicator. Stays exactly 0.0 until a
    /// second distinct pattern is observed.
    rate: f64,
    /// Informative movements of `rate` (the staleness stamp).
    moves: u64,
}

impl ChurnState {
    fn new() -> Self {
        Self { recent: Vec::with_capacity(CHURN_WINDOW), rate: 0.0, moves: 0 }
    }

    fn observe(&mut self, seed: u64) {
        if self.recent.is_empty() {
            // The first pattern ever seen is not churn evidence —
            // there was nothing to reuse. Record it and keep the rate
            // at exactly 0.0.
            self.recent.push(seed);
            return;
        }
        let hit = self.recent.iter().position(|&s| s == seed);
        let prev = self.rate;
        self.rate += CHURN_ALPHA * ((hit.is_none() as u8 as f64) - self.rate);
        if (self.rate - prev).abs() >= CHURN_INFORMATIVE_DELTA {
            self.moves += 1;
        }
        // LRU window: reuse refreshes the seed's recency, so steadily
        // reused patterns stay resident while one-shot patterns age
        // out.
        if let Some(i) = hit {
            self.recent.remove(i);
        } else if self.recent.len() >= CHURN_WINDOW {
            self.recent.remove(0);
        }
        self.recent.push(seed);
    }
}

/// Thread-safe per-pattern-geometry churn EWMAs, bounded by LRU
/// eviction. Shared between the worker pool (which observes the
/// pattern stream) and the resolver (which scores with it).
#[derive(Debug)]
pub struct ChurnTracker {
    states: Mutex<LruMap<PatternKey, ChurnState>>,
}

impl Default for ChurnTracker {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_CHURN_CAPACITY)
    }
}

impl ChurnTracker {
    pub fn with_capacity(capacity: usize) -> Self {
        Self { states: Mutex::new(LruMap::new(capacity)) }
    }

    /// Feed one observed pattern arrival at `job`'s pattern family.
    pub fn observe(&self, job: &JobSpec) {
        let mut g = locked(&self.states);
        g.get_or_insert_with(job.pattern_key(), ChurnState::new).observe(job.pattern_seed);
    }

    /// The distinct-pattern rate EWMA at `key` (0.0 when unseen or
    /// pattern-stable).
    pub fn rate(&self, key: PatternKey) -> f64 {
        locked(&self.states).peek(&key).map(|s| s.rate).unwrap_or(0.0)
    }

    /// Staleness stamp at `key`: how many times the churn EWMA has
    /// moved informatively. Memoized decisions record the stamp they
    /// were computed under and go stale once it advances by
    /// [`CHURN_MOVES_PER_REVISIT`].
    pub fn stamp(&self, key: PatternKey) -> u64 {
        locked(&self.states).peek(&key).map(|s| s.moves).unwrap_or(0)
    }

    /// Expected jobs per pattern at `key`, the amortization horizon
    /// for pattern-specific (static) planning: the reciprocal churn
    /// rate, clamped to `[1, MAX_PATTERN_LIFETIME]`; the maximum when
    /// no churn has been observed.
    pub fn expected_pattern_lifetime(&self, key: PatternKey) -> f64 {
        lifetime_for_rate(self.rate(key))
    }

    /// The amortized replan surcharge (cycles) to add to static's
    /// estimate of `static_cycles` at `job`'s pattern family: the
    /// per-pattern planning cost spread over the expected pattern
    /// lifetime. Exactly 0 while no churn has been observed, so
    /// pattern-stable and churn-blind scoring agree bit-for-bit.
    pub fn static_surcharge(&self, job: &JobSpec, static_cycles: u64) -> u64 {
        // One lock acquisition: this runs inside every workload-aware
        // resolution.
        let rate = self.rate(job.pattern_key());
        if rate == 0.0 {
            return 0;
        }
        let life = lifetime_for_rate(rate);
        (static_cycles as f64 * STATIC_REPLAN_COST_FACTOR / life).round() as u64
    }

    /// Number of pattern geometries tracked.
    pub fn geometries(&self) -> usize {
        locked(&self.states).len()
    }

    /// Entries evicted from the bounded map so far.
    pub fn evictions(&self) -> u64 {
        locked(&self.states).evictions()
    }
}

/// The clamped reciprocal-rate lifetime (see
/// [`ChurnTracker::expected_pattern_lifetime`]).
fn lifetime_for_rate(rate: f64) -> f64 {
    if rate <= 1.0 / MAX_PATTERN_LIFETIME {
        MAX_PATTERN_LIFETIME
    } else {
        (1.0 / rate).clamp(1.0, MAX_PATTERN_LIFETIME)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Mode;
    use crate::DType;

    fn job(m: usize, seed: u64) -> JobSpec {
        JobSpec {
            mode: Mode::Auto,
            m,
            k: m,
            n: 128,
            b: 16,
            density: 1.0 / 16.0,
            dtype: DType::Fp16,
            pattern_seed: seed,
        }
    }

    #[test]
    fn pattern_stable_traffic_never_registers_churn() {
        let t = ChurnTracker::default();
        let j = job(1024, 7);
        for _ in 0..100 {
            t.observe(&j);
        }
        assert_eq!(t.rate(j.pattern_key()), 0.0);
        assert_eq!(t.stamp(j.pattern_key()), 0);
        assert_eq!(t.static_surcharge(&j, 1_000_000), 0, "no churn, no surcharge");
        assert_eq!(t.expected_pattern_lifetime(j.pattern_key()), MAX_PATTERN_LIFETIME);
    }

    #[test]
    fn fresh_pattern_stream_converges_to_full_churn() {
        let t = ChurnTracker::default();
        for seed in 0..64u64 {
            t.observe(&job(1024, seed));
        }
        let key = job(1024, 0).pattern_key();
        assert!(t.rate(key) > 0.95, "rate {} after 64 fresh patterns", t.rate(key));
        assert!((1.0..=1.1).contains(&t.expected_pattern_lifetime(key)));
        // The surcharge approaches the full replan factor.
        let s = t.static_surcharge(&job(1024, 99), 1_000_000);
        let full = (1_000_000.0 * STATIC_REPLAN_COST_FACTOR) as u64;
        assert!(s > full * 9 / 10, "surcharge {s} vs full {full}");
        // And the stamp advanced while the EWMA was moving.
        assert!(t.stamp(key) >= CHURN_MOVES_PER_REVISIT);
    }

    #[test]
    fn stamp_settles_once_the_regime_converges() {
        let t = ChurnTracker::default();
        for seed in 0..200u64 {
            t.observe(&job(512, seed));
        }
        let key = job(512, 0).pattern_key();
        let settled = t.stamp(key);
        for seed in 200..240u64 {
            t.observe(&job(512, seed));
        }
        assert_eq!(t.stamp(key), settled, "a converged stream must stop moving the stamp");
    }

    #[test]
    fn window_reuse_is_not_churn_and_geometries_are_independent() {
        let t = ChurnTracker::default();
        // Two alternating seeds: the second observation is fresh, all
        // later ones hit the window.
        for i in 0..40u64 {
            t.observe(&job(2048, i % 2));
        }
        let key = job(2048, 0).pattern_key();
        assert!(t.rate(key) < 0.01, "alternating within the window decays: {}", t.rate(key));
        // An unrelated geometry saw nothing.
        assert_eq!(t.rate(job(4096, 0).pattern_key()), 0.0);
    }

    #[test]
    fn reuse_refreshes_window_recency() {
        // Seed 1 is reused mid-stream, which must refresh its window
        // slot (LRU): after eight other distinct seeds it is still
        // resident, so its next arrival decays the rate instead of
        // re-counting as fresh. (A FIFO window would have aged it out
        // by first-insertion and re-counted it.)
        let t = ChurnTracker::default();
        for s in [1u64, 2, 3, 4, 1, 5, 6, 7, 8, 9] {
            t.observe(&job(1024, s));
        }
        let key = job(1024, 0).pattern_key();
        let before = t.rate(key);
        t.observe(&job(1024, 1));
        assert!(
            t.rate(key) < before,
            "a refreshed seed must not re-count as fresh: {} -> {}",
            before,
            t.rate(key)
        );
    }

    #[test]
    fn lifetime_is_the_reciprocal_rate_mid_spectrum() {
        let t = ChurnTracker::default();
        // 1 fresh seed in every 4 arrivals (seeds cycle through a pool
        // of 3 in-window values plus a fresh one).
        let mut fresh = 1000u64;
        for i in 0..400u64 {
            let seed = if i % 4 == 0 {
                fresh += 1;
                fresh
            } else {
                i % 3
            };
            t.observe(&job(256, seed));
        }
        let key = job(256, 0).pattern_key();
        // The EWMA oscillates around the true 0.25 fresh rate (rising
        // on the fresh arrival, decaying across the three reuses);
        // sampled after a decay run it sits in the lower half.
        let rate = t.rate(key);
        assert!((0.10..0.40).contains(&rate), "rate {rate} should track the 0.25 stream");
        let life = t.expected_pattern_lifetime(key);
        assert!((2.5..10.0).contains(&life), "lifetime {life} should hover near 1/rate");
    }

    #[test]
    fn churn_map_is_bounded() {
        let t = ChurnTracker::with_capacity(16);
        for m in 1..200usize {
            t.observe(&job(16 * m, 0));
        }
        assert!(t.geometries() <= 16);
        assert!(t.evictions() > 0);
    }
}
