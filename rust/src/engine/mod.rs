//! Auto-mode execution engine: one interface over the five execution
//! paths, plus the selector that exploits the paper's crossovers.
//!
//! The paper's headline result is a *crossover structure* (Fig. 4,
//! Table 3): static block-sparse matmul beats dense on IPU only above
//! ~90% sparsity in FP16, static generally beats dynamic, and the
//! boundary moves with matrix size and block size. A serving layer
//! that forces callers to hard-code a [`Mode`] per request cannot
//! exploit any of that. This module provides:
//!
//! * [`Backend`] — a trait unifying the dense, static, dynamic,
//!   structured-N:M and (analytical) GPU execution paths behind a single
//!   `plan(&JobSpec) -> PlanEstimate` / `execute(&JobSpec) -> JobResult`
//!   interface.
//! * [`ModeSelector`] — chooses the cheapest *device-executable*
//!   backend for a `(m, k, n, b, density, dtype)` point by comparing
//!   estimated cycles, with the fitted power law of Figure 4c
//!   ([`crate::fit`]) available as a fast pre-filter for decisively
//!   sparse or decisively dense jobs.
//! * [`Calibration`] — per-(backend, geometry-bucket, dtype) EWMA
//!   correction factors learned from observed execution cycles and
//!   applied to [`PlanEstimate`] cycles before the selector's argmin,
//!   so dispatch follows measured cost rather than the analytical
//!   model alone.
//! * [`WallFeedback`] — the units-normalization layer that feeds
//!   *measured kernel wall times* (the numeric serving arm) into a
//!   calibration: one EWMA of the host's ns-per-estimated-cycle
//!   converts seconds into equivalent cycles, so factors learn the
//!   relative disagreement between cost model and measured reality —
//!   the ROADMAP's wall-time feedback item, closed without PJRT.
//! * [`ChurnTracker`] — per-pattern-geometry EWMA of the
//!   distinct-pattern rate; static's pattern-specific planning cost is
//!   amortized over the expected pattern lifetime and added to its
//!   score before the argmin, so under pattern churn dispatch shifts
//!   toward the plan-reusing backends (the workload realism the
//!   single-job crossover misses).
//!
//! [`Mode::Auto`] jobs batch under a provisional key and are resolved
//! at *batch-formation time*, at the batch's combined `n` — the
//! geometry actually executed — with resolution-time plans seeded into
//! the [`PlanCache`](crate::coordinator::PlanCache) (memoized per
//! selector key, revisited as the calibration evolves). See DESIGN.md
//! §3 and §4 for the architecture and the selection/calibration
//! lifecycle.
//!
//! [`Mode`]: crate::coordinator::request::Mode
//! [`Mode::Auto`]: crate::coordinator::request::Mode::Auto

pub mod backends;
pub mod calibration;
pub mod churn;
pub mod selector;

pub use backends::{
    backend_for, device_backends, execute_kernel, nm_plan_cycles, Backend, BackendKind,
    DenseBackend, DynamicBackend, EngineEnv, GpuBackend, KernelRun, NmBackend, PlanEstimate,
    StaticBackend,
};
pub use calibration::{
    Calibration, WallFeedback, WallScale, INFORMATIVE_DELTA, MAX_CORRECTION,
    OBSERVATIONS_PER_REVISIT, WALL_SCALE_ALPHA, WALL_WARMUP_OBSERVATIONS,
};
pub use churn::{
    CHURN_MOVES_PER_REVISIT, ChurnTracker, MAX_PATTERN_LIFETIME, STATIC_REPLAN_COST_FACTOR,
};
pub use selector::{Decision, ModeSelector, PREFILTER_MARGIN, SELECTION_TOLERANCE};
