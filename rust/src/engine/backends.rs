//! The [`Backend`] trait and its five implementations.
//!
//! Each backend turns a [`JobSpec`] into (a) a [`PlanEstimate`] — the
//! cycles the cost model predicts for the job — and (b) a full
//! [`JobResult`] when executed. Dense and static execution *is* the
//! costed plan (the simulator is the device); dynamic execution
//! additionally encodes the runtime pattern into buckets, so its
//! estimate (balanced-pattern expectation) and its executed cycles can
//! differ — exactly the gap [`crate::coordinator::Metrics`] tracks for
//! auto-mode jobs. The N:M backend serves element-granular jobs whose
//! density maps onto a supported structured N:M pattern (2:4, 4:8, …)
//! through the packed-operand fast path. The GPU backend is the
//! paper's analytical A100 baseline, reported in IPU-clock-equivalent
//! cycles so every backend is comparable on one axis.

use std::time::{Duration, Instant};

use crate::coordinator::request::{JobResult, JobSpec, Mode};
use crate::error::{Error, Result};
use crate::gpu::{self, A100Spec};
use crate::kernels::{
    self, Element, PreparedBsr, PreparedNm, PreparedOperand, Scratch, TypedScratch, F16,
};
use crate::sim::chip::{CostModel, IpuSpec};
use crate::sparse::patterns;
use crate::DType;

/// Which execution path a backend models (Table 1's API rows plus the
/// GPU baseline column).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    Dense,
    Static,
    Dynamic,
    Nm,
    Gpu,
}

impl BackendKind {
    /// The coordinator [`Mode`] this backend serves, if any. The GPU
    /// baseline is analytical only — it cannot be scheduled on the
    /// simulated device, so it maps to no mode.
    pub fn as_mode(self) -> Option<Mode> {
        match self {
            BackendKind::Dense => Some(Mode::Dense),
            BackendKind::Static => Some(Mode::Static),
            BackendKind::Dynamic => Some(Mode::Dynamic),
            BackendKind::Nm => Some(Mode::Nm),
            BackendKind::Gpu => None,
        }
    }

    /// The backend serving a concrete [`Mode`] (`None` for
    /// [`Mode::Auto`], which is a selection request, not a backend).
    pub fn of_mode(mode: Mode) -> Option<Self> {
        match mode {
            Mode::Dense => Some(BackendKind::Dense),
            Mode::Static => Some(BackendKind::Static),
            Mode::Dynamic => Some(BackendKind::Dynamic),
            Mode::Nm => Some(BackendKind::Nm),
            Mode::Auto => None,
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendKind::Dense => write!(f, "dense"),
            BackendKind::Static => write!(f, "static"),
            BackendKind::Dynamic => write!(f, "dynamic"),
            BackendKind::Nm => write!(f, "nm"),
            BackendKind::Gpu => write!(f, "gpu"),
        }
    }
}

/// Everything a backend needs to cost a job: the IPU spec, the frozen
/// calibration, and the A100 datasheet model for the GPU baseline.
#[derive(Debug, Clone)]
pub struct EngineEnv {
    pub spec: IpuSpec,
    pub cm: CostModel,
    pub gpu: A100Spec,
}

impl EngineEnv {
    pub fn new(spec: IpuSpec, cm: CostModel) -> Self {
        Self { spec, cm, gpu: A100Spec::default() }
    }
}

impl Default for EngineEnv {
    fn default() -> Self {
        Self::new(IpuSpec::default(), CostModel::default())
    }
}

/// A backend's cost prediction for one job.
#[derive(Debug, Clone)]
pub struct PlanEstimate {
    pub kind: BackendKind,
    /// Estimated device cycles (IPU-clock-equivalent for [`GpuBackend`]).
    pub cycles: u64,
    /// Estimated effective throughput. Sparse backends use the paper's
    /// non-zeros-only convention; dense counts the full GEMM.
    pub tflops: f64,
    /// Expected dynamic-mode propagation steps (0 for other backends).
    pub propagation_steps: usize,
}

/// One execution path behind a uniform plan/execute interface.
pub trait Backend: Send + Sync {
    fn kind(&self) -> BackendKind;

    /// Cost the job without committing to run it.
    fn plan(&self, job: &JobSpec, env: &EngineEnv) -> Result<PlanEstimate>;

    /// Run the job (on the simulator; numerics live in
    /// [`crate::runtime`]) and report the achieved cost.
    fn execute(&self, job: &JobSpec, env: &EngineEnv) -> Result<JobResult> {
        let t0 = Instant::now();
        let est = self.plan(job, env)?;
        Ok(result_from_estimate(job, &est, t0))
    }
}

fn result_from_estimate(job: &JobSpec, est: &PlanEstimate, t0: Instant) -> JobResult {
    JobResult {
        spec: job.clone(),
        cycles: est.cycles,
        tflops: est.tflops,
        propagation_steps: est.propagation_steps,
        plan_cache_hit: false,
        estimated_cycles: Some(est.cycles),
        service_time: t0.elapsed(),
    }
}

/// `poplin::matMul`: the dense baseline.
pub struct DenseBackend;

impl Backend for DenseBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Dense
    }

    fn plan(&self, job: &JobSpec, env: &EngineEnv) -> Result<PlanEstimate> {
        let p = crate::dense_::plan(job.m, job.k, job.n, job.dtype, &env.spec, &env.cm)?;
        Ok(PlanEstimate {
            kind: BackendKind::Dense,
            cycles: p.cost.total(),
            tflops: p.tflops(&env.spec),
            propagation_steps: 0,
        })
    }
}

/// `popsparse::static_::sparseDenseMatMul`: compile-time pattern.
pub struct StaticBackend;

impl Backend for StaticBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Static
    }

    fn plan(&self, job: &JobSpec, env: &EngineEnv) -> Result<PlanEstimate> {
        let mask =
            patterns::with_density(job.m, job.k, job.b, job.density, job.pattern_seed)?;
        let p = crate::static_::plan(&mask, job.n, job.dtype, &env.spec, &env.cm)?;
        Ok(PlanEstimate {
            kind: BackendKind::Static,
            cycles: p.cost.total(),
            tflops: p.tflops(&env.spec),
            propagation_steps: 0,
        })
    }
}

/// `popsparse::dynamic::sparseDenseMatMul`: runtime pattern. `plan`
/// reports the compile-time expectation (balanced pattern at `d_max`);
/// `execute` buckets the job's actual pattern, so skewed patterns cost
/// more than estimated — the propagation tax of Appendix A.2.
pub struct DynamicBackend;

impl Backend for DynamicBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Dynamic
    }

    fn plan(&self, job: &JobSpec, env: &EngineEnv) -> Result<PlanEstimate> {
        let p = crate::dynamic_::planner::plan(
            job.m, job.k, job.n, job.b, job.density, job.dtype, &env.spec, &env.cm,
        )?;
        let cycles = p.expected_cycles;
        Ok(PlanEstimate {
            kind: BackendKind::Dynamic,
            cycles,
            tflops: crate::tflops(
                crate::spmm_flops(job.m, job.k, job.n, job.density),
                cycles,
                env.spec.clock_hz,
            ),
            propagation_steps: 0,
        })
    }

    fn execute(&self, job: &JobSpec, env: &EngineEnv) -> Result<JobResult> {
        let t0 = Instant::now();
        let plan = crate::dynamic_::planner::plan(
            job.m, job.k, job.n, job.b, job.density, job.dtype, &env.spec, &env.cm,
        )?;
        let estimated = plan.expected_cycles;
        let mask =
            patterns::with_density(job.m, job.k, job.b, job.density, job.pattern_seed)?;
        let exec = crate::dynamic_::execute_pattern(&plan, &mask, &env.spec, &env.cm)?;
        Ok(JobResult {
            spec: job.clone(),
            cycles: exec.cost.total(),
            tflops: exec.tflops(&env.spec),
            propagation_steps: exec.propagation_steps(),
            plan_cache_hit: false,
            estimated_cycles: Some(estimated),
            service_time: t0.elapsed(),
        })
    }
}

/// Structured N:M sparsity fast path: element-granular patterns whose
/// density maps exactly onto a supported N:M structure (2:4, 4:8, …)
/// execute through the packed [`PreparedNm`] operand and its dense-like
/// gather microkernel. The cycle model scales the dense plan at the
/// same geometry by the N/M keep ratio, times a fixed gather/decode
/// overhead: the kernel streams the activation like the dense `ikj`
/// loop but touches only N of every M weight columns, paying an
/// indexed-gather tax the dense kernel does not.
pub struct NmBackend;

/// Cycle-model overhead of the N:M gather relative to an ideal
/// N/M-scaled dense pass (nibble decode + strided sliver gather).
const NM_GATHER_OVERHEAD: f64 = 1.3;

impl NmBackend {
    /// The N:M structure this job maps onto, or why it cannot: the
    /// fast path requires element-granular patterns (`b == 1`), a
    /// density expressible as a supported N/M, and `k` divisible by
    /// the group width.
    pub fn structure(job: &JobSpec) -> Result<(usize, usize)> {
        if job.b != 1 {
            return Err(Error::Plan(format!(
                "N:M path requires element-granular patterns (b=1), got b={}",
                job.b
            )));
        }
        let (nm_n, nm_m) = kernels::nm_for_density(job.density).ok_or_else(|| {
            Error::Plan(format!(
                "density {} maps onto no supported N:M structure",
                job.density
            ))
        })?;
        if job.k % nm_m != 0 {
            return Err(Error::Plan(format!(
                "k={} is not divisible by the N:M group width {nm_m}",
                job.k
            )));
        }
        Ok((nm_n, nm_m))
    }
}

/// The N:M cycle model: the dense plan at the same geometry scaled by
/// the N/M keep ratio times the gather overhead. Shared by
/// [`NmBackend::plan`] and the plan cache's N:M build arm
/// ([`crate::coordinator::PlanCache`]) so the two cannot drift.
pub fn nm_plan_cycles(job: &JobSpec, spec: &IpuSpec, cm: &CostModel) -> Result<u64> {
    let (nm_n, nm_m) = NmBackend::structure(job)?;
    let dense = crate::dense_::plan(job.m, job.k, job.n, job.dtype, spec, cm)?;
    let keep = nm_n as f64 / nm_m as f64;
    Ok(((dense.cost.total() as f64 * keep * NM_GATHER_OVERHEAD).ceil() as u64).max(1))
}

impl Backend for NmBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Nm
    }

    fn plan(&self, job: &JobSpec, env: &EngineEnv) -> Result<PlanEstimate> {
        let cycles = nm_plan_cycles(job, &env.spec, &env.cm)?;
        Ok(PlanEstimate {
            kind: BackendKind::Nm,
            cycles,
            tflops: crate::tflops(
                crate::spmm_flops(job.m, job.k, job.n, job.density),
                cycles,
                env.spec.clock_hz,
            ),
            propagation_steps: 0,
        })
    }
}

/// Analytical A100 baseline: cuBLAS for dense work, cuSPARSE CSR for
/// unstructured patterns, cuSPARSE BSR (FP32-only, as the real API)
/// for block patterns. Reported in IPU-clock-equivalent cycles.
pub struct GpuBackend;

impl GpuBackend {
    fn seconds(job: &JobSpec, env: &EngineEnv) -> Result<f64> {
        if job.density >= 1.0 {
            return Ok(gpu::cublas::gemm_seconds(job.m, job.k, job.n, job.dtype, &env.gpu));
        }
        if job.b == 0 || job.m % job.b != 0 || job.k % job.b != 0 {
            return Err(Error::Plan(format!(
                "bad dims m={} k={} b={}",
                job.m, job.k, job.b
            )));
        }
        if job.b == 1 {
            let nnz = ((job.m * job.k) as f64 * job.density).round() as usize;
            return Ok(gpu::cusparse_csr::csr_spmm_seconds(
                job.m, job.k, job.n, nnz, job.dtype, &env.gpu,
            ));
        }
        let grid = (job.m / job.b) * (job.k / job.b);
        let nnz_b = ((grid as f64 * job.density).round() as usize).clamp(1, grid);
        // cusparseSbsrmm is FP32-only (paper Table 1): FP16 jobs are
        // modelled on the FP32 path, the best the real API offers.
        gpu::cusparse_bsr::bsrmm_seconds(
            job.m,
            job.k,
            job.n,
            nnz_b,
            job.b,
            DType::Fp32,
            &env.gpu,
        )
        .ok_or_else(|| Error::Plan("cusparse BSR rejected the configuration".into()))
    }
}

impl Backend for GpuBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Gpu
    }

    fn plan(&self, job: &JobSpec, env: &EngineEnv) -> Result<PlanEstimate> {
        let secs = Self::seconds(job, env)?;
        let d = if job.density >= 1.0 { 1.0 } else { job.density };
        let flops = crate::spmm_flops(job.m, job.k, job.n, d);
        Ok(PlanEstimate {
            kind: BackendKind::Gpu,
            cycles: (secs * env.spec.clock_hz).ceil() as u64,
            tflops: flops / secs / 1e12,
            propagation_steps: 0,
        })
    }
}

/// One native-kernel numeric execution: the measured wall time and
/// the FLOPs it performed (nnz-only for sparse jobs — the paper's
/// throughput convention).
#[derive(Debug, Clone, Copy)]
pub struct KernelRun {
    pub wall: Duration,
    pub flops: f64,
}

impl KernelRun {
    /// Achieved throughput in GFLOP/s.
    pub fn gflops(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.flops / self.wall.as_secs_f64() / 1e9
    }
}

/// Numerically execute `job` through the native compute layer
/// ([`crate::kernels`]) **in the job's declared dtype**: the actual
/// SpMM/GEMM this machine can *time* (f32 storage, or f16 storage
/// with f32 accumulation — the AMP contract), complementing the
/// simulated device cycles the backends' `plan`/`execute` report.
/// Sparse modes run the prepared tiled kernel — a caller holding the
/// pattern's cached [`PreparedOperand`] (the coordinator's plan
/// cache) passes it via `prepared`, `None` converts from the job's
/// pattern seed; a dtype mismatch between the handle and the job is a
/// caller bug and errors rather than silently widening — and dense
/// jobs run the `ikj`-tiled kernel. Operands are deterministic
/// pseudo-data from the matching half of `scratch` (reused across
/// calls; nothing allocates at steady state in either precision), and
/// the output stays in the scratch for oracle checks. `threads`
/// bounds the row-panel parallelism; `spmm_auto` decides whether the
/// job is large enough to spend it.
pub fn execute_kernel(
    job: &JobSpec,
    prepared: Option<&PreparedOperand>,
    scratch: &mut Scratch,
    threads: usize,
) -> Result<KernelRun> {
    if let Some(p) = prepared {
        if p.dtype() != job.dtype {
            return Err(Error::InvalidFormat(format!(
                "prepared operand is {} but the job executes in {}",
                p.dtype(),
                job.dtype
            )));
        }
    }
    if job.mode == Mode::Nm {
        return match job.dtype {
            DType::Fp32 => execute_nm_typed::<f32>(
                job,
                prepared.and_then(PreparedOperand::as_nm_f32).map(|p| p.as_ref()),
                scratch.fp32(),
                threads,
            ),
            DType::Fp16 => execute_nm_typed::<F16>(
                job,
                prepared.and_then(PreparedOperand::as_nm_f16).map(|p| p.as_ref()),
                scratch.fp16(),
                threads,
            ),
        };
    }
    match job.dtype {
        DType::Fp32 => execute_typed::<f32>(
            job,
            prepared.and_then(PreparedOperand::as_f32).map(|p| p.as_ref()),
            scratch.fp32(),
            threads,
        ),
        DType::Fp16 => execute_typed::<F16>(
            job,
            prepared.and_then(PreparedOperand::as_f16).map(|p| p.as_ref()),
            scratch.fp16(),
            threads,
        ),
    }
}

/// The monomorphized N:M execution behind [`execute_kernel`]: the
/// packed operand (cached handle or converted from the job's pattern
/// seed) through [`kernels::spmm_nm_auto`] on the job's scratch half.
fn execute_nm_typed<E: Element>(
    job: &JobSpec,
    prepared: Option<&PreparedNm<E>>,
    scratch: &mut TypedScratch<E>,
    threads: usize,
) -> Result<KernelRun> {
    let (nm_n, nm_m) = NmBackend::structure(job)?;
    let converted;
    let prep = match prepared {
        Some(p) => p,
        None => {
            converted =
                PreparedNm::<E>::from_pattern(job.m, job.k, nm_n, nm_m, job.pattern_seed)?;
            &converted
        }
    };
    let (x, y) = scratch.spmm_operands(job.m, job.k, job.n);
    let t0 = Instant::now();
    kernels::spmm_nm_auto(prep, x, job.n, y, threads)?;
    Ok(KernelRun { wall: t0.elapsed(), flops: job.flops() })
}

/// The monomorphized execution behind [`execute_kernel`]: one storage
/// element, one scratch half.
fn execute_typed<E: Element>(
    job: &JobSpec,
    prepared: Option<&PreparedBsr<E>>,
    scratch: &mut TypedScratch<E>,
    threads: usize,
) -> Result<KernelRun> {
    match job.mode {
        Mode::Dense => {
            let (a, x, y) = scratch.dense_operands(job.m, job.k, job.n);
            let t0 = Instant::now();
            kernels::dense::matmul_auto(a, x, job.m, job.k, job.n, y, threads)?;
            Ok(KernelRun { wall: t0.elapsed(), flops: job.flops() })
        }
        Mode::Static | Mode::Dynamic => {
            let converted;
            let prep = match prepared {
                Some(p) => p,
                None => {
                    converted = PreparedBsr::<E>::from_pattern(
                        job.m,
                        job.k,
                        job.b,
                        job.density,
                        job.pattern_seed,
                    )?;
                    &converted
                }
            };
            let (x, y) = scratch.spmm_operands(job.m, job.k, job.n);
            let t0 = Instant::now();
            kernels::spmm_auto(prep, x, job.n, y, threads)?;
            Ok(KernelRun { wall: t0.elapsed(), flops: job.flops() })
        }
        Mode::Nm => Err(Error::Coordinator(
            "nm jobs dispatch through the dedicated N:M arm of execute_kernel".into(),
        )),
        Mode::Auto => Err(Error::Coordinator(
            "auto-mode jobs must be resolved to a concrete mode before numeric execution".into(),
        )),
    }
}

/// The device-executable backends, in the order the selector evaluates
/// them (the GPU baseline is analytical only and excluded). The N:M
/// backend is appended *last* so the corrected-argmin's first-minimum
/// tie-break keeps every legacy decision unchanged; it rejects any job
/// its feasibility gate does not cover ([`NmBackend::structure`]) and
/// is simply skipped there.
pub fn device_backends() -> [&'static dyn Backend; 4] {
    [&DenseBackend, &StaticBackend, &DynamicBackend, &NmBackend]
}

/// Look up a backend by kind.
pub fn backend_for(kind: BackendKind) -> &'static dyn Backend {
    match kind {
        BackendKind::Dense => &DenseBackend,
        BackendKind::Static => &StaticBackend,
        BackendKind::Dynamic => &DynamicBackend,
        BackendKind::Nm => &NmBackend,
        BackendKind::Gpu => &GpuBackend,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(density: f64, b: usize) -> JobSpec {
        JobSpec {
            mode: Mode::Auto,
            m: 1024,
            k: 1024,
            n: 512,
            b,
            density,
            dtype: DType::Fp16,
            pattern_seed: 7,
        }
    }

    #[test]
    fn all_backends_plan_the_paper_point() {
        let env = EngineEnv::default();
        let j = job(1.0 / 16.0, 16);
        for backend in device_backends() {
            if backend.kind() == BackendKind::Nm {
                // The paper point is block-granular: outside the N:M
                // feasibility gate, so the candidate bows out with an
                // error rather than a bogus estimate.
                assert!(backend.plan(&j, &env).is_err());
                continue;
            }
            let e = backend.plan(&j, &env).unwrap();
            assert!(e.cycles > 0, "{:?}: zero cycles", e.kind);
            assert!(e.tflops > 0.0);
            assert_eq!(e.kind, backend.kind());
        }
        let g = GpuBackend.plan(&j, &env).unwrap();
        assert!(g.cycles > 0 && g.tflops > 0.0);
    }

    #[test]
    fn nm_backend_gates_feasibility_and_undercuts_dense() {
        let env = EngineEnv::default();
        // 2:4-expressible job: element-granular, density 1/2.
        let mut j = job(0.5, 1);
        let e = NmBackend.plan(&j, &env).unwrap();
        assert_eq!(e.kind, BackendKind::Nm);
        assert!(e.cycles > 0 && e.tflops > 0.0);
        let d = DenseBackend.plan(&j, &env).unwrap();
        assert!(
            e.cycles < d.cycles,
            "N:M keep-ratio scaling must undercut dense at the same geometry: {} vs {}",
            e.cycles,
            d.cycles
        );
        // Execution is its plan (like static).
        let r = NmBackend.execute(&j, &env).unwrap();
        assert_eq!(Some(r.cycles), r.estimated_cycles);
        // Gate: block-granular, unmappable density, indivisible k.
        assert!(NmBackend.plan(&job(0.5, 16), &env).is_err());
        assert!(NmBackend.plan(&job(1.0 / 3.0, 1), &env).is_err());
        j.k = 1026; // not divisible by 4
        assert!(NmBackend.plan(&j, &env).is_err());
    }

    #[test]
    fn nm_kernel_execution_matches_numeric_oracle() {
        let mut j = job(0.5, 1);
        j.mode = Mode::Nm;
        j.dtype = DType::Fp32;
        j.m = 64;
        j.k = 64;
        j.n = 33; // exercises the n-tile remainder
        let mut scratch = Scratch::default();
        let x = scratch.spmm_operands(j.m, j.k, j.n).0.to_vec();
        let run = execute_kernel(&j, None, &mut scratch, 2).unwrap();
        assert!(run.flops > 0.0);
        let prep =
            PreparedNm::<f32>::from_pattern(j.m, j.k, 2, 4, j.pattern_seed).unwrap();
        let a = prep.to_dense();
        let expect = crate::runtime::dense_ref(&a, &x, j.m, j.k, j.n);
        for (i, (&u, &v)) in scratch.output().iter().zip(&expect).enumerate() {
            assert!(kernels::close_enough(u, v), "nm: element {i}: {u} vs {v}");
        }
        // A cached prepared handle must agree with the fresh path.
        let cached = PreparedOperand::from_nm_pattern(j.m, j.k, 2, 4, j.pattern_seed, j.dtype)
            .unwrap();
        let y_fresh = scratch.output().to_vec();
        execute_kernel(&j, Some(&cached), &mut scratch, 2).unwrap();
        assert_eq!(scratch.output(), &y_fresh[..], "cached and fresh operands agree");
    }

    #[test]
    fn static_never_exceeds_dynamic_execution() {
        // Table 3's invariant, through the engine interface: dynamic
        // *execution* (the actual bucketed pattern) never beats static
        // on the same uniform problem. The dynamic plan estimate alone
        // is a balanced-pattern expectation and may undercut static by
        // a sliver near ties — which is exactly why the selector's
        // tolerance is documented rather than assumed zero.
        let env = EngineEnv::default();
        for b in [4usize, 8, 16] {
            let j = job(1.0 / 8.0, b);
            let st = StaticBackend.plan(&j, &env).unwrap();
            let dy = DynamicBackend.execute(&j, &env).unwrap();
            assert!(
                st.cycles <= dy.cycles,
                "b={b}: static {} > dynamic execution {}",
                st.cycles,
                dy.cycles
            );
        }
    }

    #[test]
    fn execute_reports_estimate_and_cycles() {
        let env = EngineEnv::default();
        let j = job(1.0 / 16.0, 16);
        let r = DynamicBackend.execute(&j, &env).unwrap();
        assert!(r.cycles > 0);
        assert_eq!(r.spec.m, 1024);
        let est = r.estimated_cycles.expect("engine executes carry estimates");
        assert!(est > 0);
        let s = StaticBackend.execute(&j, &env).unwrap();
        assert_eq!(Some(s.cycles), s.estimated_cycles, "static execution is its plan");
    }

    #[test]
    fn gpu_backend_is_fp32_bound_for_blocks() {
        // FP16 block-sparse jobs fall back to the FP32 BSR path, so the
        // dtype does not change the estimate (paper Table 1).
        let env = EngineEnv::default();
        let mut j16 = job(1.0 / 16.0, 16);
        let mut j32 = j16.clone();
        j16.dtype = DType::Fp16;
        j32.dtype = DType::Fp32;
        let a = GpuBackend.plan(&j16, &env).unwrap();
        let b = GpuBackend.plan(&j32, &env).unwrap();
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn kernel_execution_matches_numeric_oracle() {
        // The backends' numeric arm runs on crate::kernels; its f32
        // output must agree with the naive reference on the same
        // operands within the documented kernel tolerance (not
        // bit-equality — the tiled path reorders f32 partial sums).
        let mut j = job(1.0 / 8.0, 8);
        j.dtype = DType::Fp32;
        j.m = 256;
        j.k = 256;
        j.n = 33; // exercises the n-tile remainder
        let mut scratch = Scratch::default();
        for mode in [Mode::Static, Mode::Dynamic] {
            j.mode = mode;
            // Pin the operand contents first, then execute at the same
            // shape (the scratch refills only on resize).
            let x = scratch.spmm_operands(j.m, j.k, j.n).0.to_vec();
            let run = execute_kernel(&j, None, &mut scratch, 2).unwrap();
            assert!(run.flops > 0.0);
            let mask =
                patterns::with_density(j.m, j.k, j.b, j.density, j.pattern_seed).unwrap();
            let coo = patterns::with_values(&mask, j.pattern_seed);
            let expect = coo.spmm_dense(&x, j.n).unwrap();
            for (i, (&u, &v)) in scratch.output().iter().zip(&expect).enumerate() {
                assert!(kernels::close_enough(u, v), "{mode}: element {i}: {u} vs {v}");
            }
        }
        j.mode = Mode::Dense;
        let (a, x, _) = scratch.dense_operands(j.m, j.k, j.n);
        let (a, x) = (a.to_vec(), x.to_vec());
        let run = execute_kernel(&j, None, &mut scratch, 2).unwrap();
        assert!(run.gflops() > 0.0);
        let expect = crate::runtime::dense_ref(&a, &x, j.m, j.k, j.n);
        for (i, (&u, &v)) in scratch.output().iter().zip(&expect).enumerate() {
            assert!(kernels::close_enough(u, v), "dense: element {i}: {u} vs {v}");
        }
    }

    #[test]
    fn fp16_jobs_execute_in_f16_storage() {
        // A declared-FP16 job must run the F16 kernel on the f16
        // scratch half — output lands in f16 storage and agrees with
        // the f32 oracle on the quantized operands within the f16
        // contract.
        let mut j = job(1.0 / 8.0, 8);
        assert_eq!(j.dtype, DType::Fp16);
        j.mode = Mode::Static;
        j.m = 128;
        j.k = 128;
        j.n = 33;
        let mut scratch = Scratch::default();
        let x16 = scratch.fp16().spmm_operands(j.m, j.k, j.n).0.to_vec();
        let run = execute_kernel(&j, None, &mut scratch, 1).unwrap();
        assert!(run.flops > 0.0);
        assert!(scratch.output().is_empty(), "the f32 half must stay untouched");
        let prep16 = PreparedBsr::<F16>::from_pattern(
            j.m, j.k, j.b, j.density, j.pattern_seed,
        )
        .unwrap();
        let expect = prep16
            .to_block_coo()
            .unwrap()
            .spmm_dense(&kernels::dequantize(&x16), j.n)
            .unwrap();
        for (i, (&u, &v)) in
            kernels::dequantize(scratch.output_f16()).iter().zip(&expect).enumerate()
        {
            assert!(
                kernels::close_enough_for(DType::Fp16, u, v),
                "element {i}: {u} vs {v}"
            );
        }
    }

    #[test]
    fn kernel_execution_accepts_cached_prepared_operand() {
        let mut j = job(1.0 / 8.0, 16);
        j.mode = Mode::Static;
        j.m = 128;
        j.k = 128;
        j.n = 16;
        for dtype in [DType::Fp32, DType::Fp16] {
            j.dtype = dtype;
            let prep = PreparedOperand::from_pattern(
                j.m, j.k, j.b, j.density, j.pattern_seed, dtype,
            )
            .unwrap();
            let mut scratch = Scratch::default();
            let cached = execute_kernel(&j, Some(&prep), &mut scratch, 1).unwrap();
            let y_cached = match dtype {
                DType::Fp32 => scratch.output().to_vec(),
                DType::Fp16 => kernels::dequantize(scratch.output_f16()),
            };
            let fresh = execute_kernel(&j, None, &mut scratch, 1).unwrap();
            let y_fresh = match dtype {
                DType::Fp32 => scratch.output().to_vec(),
                DType::Fp16 => kernels::dequantize(scratch.output_f16()),
            };
            assert_eq!(y_cached, y_fresh, "{dtype}: cached and fresh operands agree");
            assert_eq!(cached.flops, fresh.flops);
        }
        // A dtype-mismatched handle is a caller bug, not a silent
        // widening.
        j.dtype = DType::Fp16;
        let wrong =
            PreparedOperand::from_pattern(j.m, j.k, j.b, j.density, j.pattern_seed, DType::Fp32)
                .unwrap();
        let mut scratch = Scratch::default();
        assert!(execute_kernel(&j, Some(&wrong), &mut scratch, 1).is_err());
        let mut auto = j.clone();
        auto.mode = Mode::Auto;
        assert!(execute_kernel(&auto, None, &mut scratch, 1).is_err());
    }

    #[test]
    fn kinds_map_to_modes() {
        assert_eq!(BackendKind::Dense.as_mode(), Some(Mode::Dense));
        assert_eq!(BackendKind::Static.as_mode(), Some(Mode::Static));
        assert_eq!(BackendKind::Dynamic.as_mode(), Some(Mode::Dynamic));
        assert_eq!(BackendKind::Nm.as_mode(), Some(Mode::Nm));
        assert_eq!(BackendKind::Gpu.as_mode(), None);
        for kind in [
            BackendKind::Dense,
            BackendKind::Static,
            BackendKind::Dynamic,
            BackendKind::Nm,
        ] {
            assert_eq!(BackendKind::of_mode(kind.as_mode().unwrap()), Some(kind));
        }
        assert_eq!(BackendKind::of_mode(Mode::Auto), None);
    }
}
