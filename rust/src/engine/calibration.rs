//! Observed-cycle calibration: closing the estimation loop.
//!
//! The selector decides between backends by *analytical* cycle
//! estimates (the planners' cost models). Gale et al.'s sparse GPU
//! kernels and the Sparsity Roofline both argue that measured kernel
//! cost, not analytical cost alone, should drive dispatch: cost models
//! drift from realized cycles in backend-specific, geometry-dependent
//! ways (here most visibly in dynamic mode, whose plan estimate is a
//! balanced-pattern expectation while execution buckets the *actual*
//! pattern). [`Calibration`] keeps one EWMA correction factor per
//! (backend, geometry-bucket): the ratio of observed execution cycles
//! to the raw estimate, learned from the simulator/interpreter as
//! batches complete, and applied to [`PlanEstimate`] cycles *before*
//! the selector's argmin.
//!
//! Guarantee: calibrated selection preserves the documented
//! [`SELECTION_TOLERANCE`](crate::engine::SELECTION_TOLERANCE) bound
//! *with respect to corrected estimates* — the full path is still an
//! exact argmin, only over corrected values, and factors are clamped
//! to [`MAX_CORRECTION`] so a burst of skewed observations cannot pin
//! a backend arbitrarily far from its model. With identity
//! observations (observed == estimated) every factor stays at 1.0 and
//! corrected estimates equal raw estimates — calibration is a strict
//! no-op until the observed stream disagrees with the model
//! (`rust/tests/property_selection.rs` pins both properties).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use crate::coordinator::request::JobSpec;
use crate::engine::backends::{BackendKind, PlanEstimate};
use crate::util::LruMap;
use crate::DType;

/// Default EWMA smoothing weight for new observations.
pub const DEFAULT_ALPHA: f64 = 0.25;

/// Default capacity of the (backend, geometry-bucket) factor map.
/// Buckets are power-of-two coarse, so paper-scale traffic touches a
/// few dozen — the bound exists for open-world traffic, where the key
/// population is adversarial. Evicting a bucket forgets its learned
/// correction (it restarts at 1.0 if the geometry returns), which is
/// safe: factors only steer selection, never execution.
pub const DEFAULT_CALIBRATION_CAPACITY: usize = 4096;

/// Correction factors are clamped to `[1/MAX_CORRECTION,
/// MAX_CORRECTION]`: calibration may reshape the frontier, but a
/// pathological observation stream cannot move any backend more than
/// this factor away from its analytical estimate.
pub const MAX_CORRECTION: f64 = 4.0;

/// A memoized auto-mode decision goes stale — and is revisited — once
/// its *own geometry* accumulates this many new informative
/// observations (see [`Calibration::geometry_stamp`]): often enough
/// that the frontier tracks the observed stream, rarely enough that
/// the memo still amortises selection, and confined to the decisions
/// the new observations could actually flip. Re-selection is cheap
/// because resolution plans live in the plan cache.
pub const OBSERVATIONS_PER_REVISIT: u64 = 16;

/// An observation is *informative* — advances its bucket's update
/// count and thereby re-opens memoized decisions at that geometry —
/// only when its observed/estimated ratio disagrees with the bucket's
/// *current* factor by at least this much. Observations that confirm
/// what the calibration already believes (identity ratios at an
/// untouched bucket — dense and static execute exactly at their
/// estimates on the simulator — or a converged stream at any factor)
/// still count toward [`Calibration::observations`] but carry no
/// information that could flip a decision, so they must not churn the
/// decision memo. Crucially the gate is relative to the factor, not
/// to 1.0: identity observations arriving at a bucket that had
/// learned a correction *are* informative — they un-learn it — and
/// must re-open the memo so decisions can swing back.
pub const INFORMATIVE_DELTA: f64 = 0.01;

/// Geometry bucket a correction factor applies to: backend kind plus
/// the job's shape quantized to powers of two (and the density decade).
/// Coarse on purpose — correction factors model *systematic* estimate
/// bias per regime, not per-point noise, and coarse buckets let a few
/// observations generalize to neighbouring geometries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BucketKey {
    pub kind: BackendKind,
    pub log2_m: u32,
    pub log2_k: u32,
    pub log2_n: u32,
    pub b: usize,
    /// `round(-log2(density))`: 0 for dense, 4 for d=1/16, ...
    pub log2_inv_density: i32,
    pub dtype: DType,
}

impl BucketKey {
    pub fn of(kind: BackendKind, job: &JobSpec) -> Self {
        let d = job.density.clamp(1e-9, 1.0);
        Self {
            kind,
            log2_m: job.m.max(1).ilog2(),
            log2_k: job.k.max(1).ilog2(),
            log2_n: job.n.max(1).ilog2(),
            b: job.b,
            log2_inv_density: (-d.log2()).round() as i32,
            dtype: job.dtype,
        }
    }
}

/// Per-(backend, geometry-bucket) EWMA correction factors over
/// observed-vs-estimated execution cycles. Thread-safe; shared between
/// the worker pool (which observes) and the resolver (which corrects).
/// One bucket's learned state: the EWMA factor plus how many
/// informative observations have shaped it (the staleness signal for
/// decisions memoized against this bucket).
#[derive(Debug, Clone, Copy)]
struct Ewma {
    factor: f64,
    informative: u64,
}

#[derive(Debug)]
pub struct Calibration {
    alpha: f64,
    factors: Mutex<LruMap<BucketKey, Ewma>>,
    observations: AtomicU64,
}

/// Poison-tolerant lock: a panicked worker thread must not make the
/// calibration (or the shard it lives on) unreadable for shutdown
/// reporting or the surviving shards' aggregate accessors. Every value
/// here is a self-consistent EWMA scalar, so observing a
/// mid-panic state is safe — at worst one observation is lost.
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Default for Calibration {
    fn default() -> Self {
        Self::new(DEFAULT_ALPHA)
    }
}

impl Calibration {
    pub fn new(alpha: f64) -> Self {
        Self::with_capacity(alpha, DEFAULT_CALIBRATION_CAPACITY)
    }

    /// A calibration whose factor map holds at most `capacity`
    /// (backend, geometry-bucket) entries, evicted LRU — recency is
    /// refreshed by both corrections and observations, so the buckets
    /// live traffic leans on stay resident.
    pub fn with_capacity(alpha: f64, capacity: usize) -> Self {
        Self {
            alpha: alpha.clamp(0.0, 1.0),
            factors: Mutex::new(LruMap::new(capacity)),
            observations: AtomicU64::new(0),
        }
    }

    /// The correction factor for this backend at this job's geometry
    /// bucket (1.0 when nothing has been observed yet).
    pub fn factor(&self, kind: BackendKind, job: &JobSpec) -> f64 {
        let key = BucketKey::of(kind, job);
        locked(&self.factors).get(&key).map(|e| e.factor).unwrap_or(1.0)
    }

    /// Apply the bucket's correction to a raw cycle estimate.
    pub fn correct(&self, kind: BackendKind, job: &JobSpec, raw_cycles: u64) -> u64 {
        let corrected = raw_cycles as f64 * self.factor(kind, job);
        (corrected.round() as u64).max(1)
    }

    /// Feed one observed execution back: `estimated` is the raw
    /// (uncorrected) cycle estimate the plan carried, `observed` the
    /// cycles the simulator/interpreter actually reported. Zero on
    /// either side is ignored (nothing to learn from).
    pub fn observe(&self, kind: BackendKind, job: &JobSpec, estimated: u64, observed: u64) {
        if estimated == 0 || observed == 0 {
            return;
        }
        let ratio =
            (observed as f64 / estimated as f64).clamp(1.0 / MAX_CORRECTION, MAX_CORRECTION);
        let key = BucketKey::of(kind, job);
        let mut factors = locked(&self.factors);
        let e = factors.get_or_insert_with(key, || Ewma { factor: 1.0, informative: 0 });
        if (ratio - e.factor).abs() >= INFORMATIVE_DELTA {
            e.informative += 1;
        }
        e.factor = (e.factor + self.alpha * (ratio - e.factor))
            .clamp(1.0 / MAX_CORRECTION, MAX_CORRECTION);
        drop(factors);
        self.observations.fetch_add(1, Ordering::Relaxed);
    }

    /// Total observations fed in so far.
    pub fn observations(&self) -> u64 {
        self.observations.load(Ordering::Relaxed)
    }

    /// Staleness stamp for decisions at `job`'s geometry: the total
    /// informative observations (ratio disagreeing with the bucket's
    /// current factor by at least [`INFORMATIVE_DELTA`]) across the
    /// device-backend buckets the decision depends on. Memoized
    /// resolutions record the stamp they were computed under and go
    /// stale once it has advanced by [`OBSERVATIONS_PER_REVISIT`] —
    /// so only geometries whose observed stream actually moved (in
    /// either direction: learning a correction or un-learning one)
    /// get revisited, while confirming observations — e.g. explicit
    /// dense/static traffic, whose simulated executions equal their
    /// estimates by construction — never churn the memo.
    pub fn geometry_stamp(&self, job: &JobSpec) -> u64 {
        let factors = locked(&self.factors);
        [BackendKind::Dense, BackendKind::Static, BackendKind::Dynamic, BackendKind::Nm]
            .iter()
            .map(|&kind| {
                factors.peek(&BucketKey::of(kind, job)).map(|e| e.informative).unwrap_or(0)
            })
            .sum()
    }

    /// Number of (backend, geometry-bucket) factors tracked.
    pub fn buckets(&self) -> usize {
        locked(&self.factors).len()
    }

    /// Bucket-map eviction accounting: (evictions,
    /// misses-after-evict). The second number counts lookups that
    /// found their bucket gone — learned corrections the bound threw
    /// away and traffic then asked for.
    pub fn eviction_stats(&self) -> (u64, u64) {
        let g = locked(&self.factors);
        (g.evictions(), g.misses_after_evict())
    }

    /// All tracked factors, for reporting.
    pub fn snapshot(&self) -> Vec<(BucketKey, f64)> {
        let mut v: Vec<(BucketKey, f64)> =
            locked(&self.factors).iter().map(|(k, e)| (*k, e.factor)).collect();
        v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        v
    }
}

/// First-minimum argmin over `estimates` by *corrected* cycles (raw
/// cycles when `calibration` is `None`). Returns the winning estimate
/// and its corrected value. Both the selector's full path
/// ([`ModeSelector::choose_with`](crate::engine::ModeSelector::choose_with))
/// and the plan cache's batch resolver funnel through this one
/// function, so their argmin (including tie-breaking on the backend
/// evaluation order) cannot drift apart —
/// `rust/tests/property_selection.rs` pins the agreement end to end.
pub fn corrected_argmin<'a>(
    estimates: &'a [PlanEstimate],
    calibration: Option<&Calibration>,
    job: &JobSpec,
) -> Option<(&'a PlanEstimate, u64)> {
    corrected_argmin_amortized(estimates, calibration, job, 0)
}

/// [`corrected_argmin`] with workload-aware amortization: the static
/// candidate is *scored* with `static_surcharge` extra cycles (the
/// per-pattern replan cost over the expected pattern lifetime — see
/// [`ChurnTracker::static_surcharge`](crate::engine::ChurnTracker::static_surcharge)),
/// so under pattern churn the argmin shifts away from static. The
/// surcharge steers the comparison only: the returned corrected value
/// is the winner's corrected *execution* estimate, without the
/// surcharge, so downstream estimate-accuracy accounting stays honest
/// against simulated cycles. With `static_surcharge == 0` this is
/// exactly [`corrected_argmin`] — the single argmin definition every
/// selection path funnels through.
pub fn corrected_argmin_amortized<'a>(
    estimates: &'a [PlanEstimate],
    calibration: Option<&Calibration>,
    job: &JobSpec,
    static_surcharge: u64,
) -> Option<(&'a PlanEstimate, u64)> {
    let mut best: Option<(&PlanEstimate, u64, u64)> = None;
    for e in estimates {
        let corrected = match calibration {
            Some(c) => c.correct(e.kind, job, e.cycles),
            None => e.cycles,
        };
        let score = if e.kind == BackendKind::Static {
            corrected.saturating_add(static_surcharge)
        } else {
            corrected
        };
        let better = match best {
            None => true,
            Some((_, _, best_score)) => score < best_score,
        };
        if better {
            best = Some((e, corrected, score));
        }
    }
    best.map(|(e, corrected, _)| (e, corrected))
}

/// Default EWMA weight for the wall-per-cycle scale ([`WallFeedback`]).
/// Deliberately slower than the factor EWMA: the scale is a property
/// of the *host*, not of any backend, so it should average across the
/// whole observation stream rather than chase the latest kernel.
pub const WALL_SCALE_ALPHA: f64 = 0.1;

/// Scale observations required before [`WallFeedback`] starts feeding
/// normalized observations into its calibration. Until the
/// wall-per-cycle scale has seen this many samples it is dominated by
/// whichever backend happened to run first, and normalized ratios
/// would encode startup noise rather than backend-relative cost.
pub const WALL_WARMUP_OBSERVATIONS: u64 = 8;

/// Measured-wall-time feedback into a [`Calibration`] — the
/// units-normalization layer that closes the ROADMAP's "feed measured
/// wall times into `Calibration::observe`" item without a PJRT
/// backend.
///
/// `Calibration` learns from *cycle* ratios; a kernel measurement is
/// *seconds*. The two are bridged by one EWMA of the host's
/// nanoseconds-per-estimated-cycle over every observation
/// ([`WALL_SCALE_ALPHA`]): an incoming wall time is divided by the
/// current scale to yield equivalent observed cycles, then fed into
/// the wrapped calibration against the plan's raw cycle estimate.
/// Absolute host speed cancels out — a uniformly slow machine moves
/// the scale, not the factors — so what the factors learn is exactly
/// the *relative* disagreement between the cost model and measured
/// wall time per (backend, geometry-bucket, dtype) (the bucket key
/// carries the dtype, so FP16 and FP32 kernels calibrate
/// independently). A backend whose kernels run slow *per estimated
/// cycle* relative to the traffic-wide mean accumulates a factor
/// above 1 and loses argmin ties it used to win; see
/// `wall_fed_calibration_flips_a_skewed_argmin` for the end-to-end
/// property.
///
/// Optionally, a measured machine roofline
/// ([`crate::kernels::MachineRoofline`]) can be armed as a **physical
/// floor** under incoming walls ([`WallFeedback::arm_roofline`]): no
/// real kernel can finish faster than its compulsory traffic at peak
/// bandwidth or its flops at peak FLOP rate, so a wall below that
/// floor is a measurement bug (timer glitch, wrong geometry attached
/// to the sample) or a traffic-model bug — counted in
/// [`WallFeedback::roofline_violations`] as a sanity signal. The
/// sample still feeds calibration, but *floored to the physical
/// minimum*: letting a physically-impossible wall through unclamped
/// would teach the backend a factor far below reality and could flip
/// an argmin toward a backend on the strength of a timer glitch
/// (`clamped_absurd_walls_cannot_flip_the_argmin` pins this).
#[derive(Debug)]
pub struct WallFeedback {
    calibration: Calibration,
    scale: Arc<WallScale>,
    fed: AtomicU64,
    /// Armed roofline peaks as f64 bits (0.0 bits = unarmed).
    roofline_gflops_bits: AtomicU64,
    roofline_gbps_bits: AtomicU64,
    roofline_violations: AtomicU64,
}

/// The host's nanoseconds-per-estimated-cycle EWMA, kept lock-free so
/// the numeric hot path never serializes on it: `samples` is claimed
/// with a fetch-add and the scale itself is f64 bits behind a CAS
/// update loop. This is the one piece of state the sharded coordinator
/// genuinely shares across workers (the scale is a property of the
/// *host*, so per-shard copies would each re-pay warm-up and drift
/// apart) — shared as atomically-published values, never a mutex.
///
/// Sequential callers (trace replay, the unit tests) see exactly the
/// old mutex semantics: sample 1 seeds the scale to its own ratio,
/// later samples EWMA toward theirs. Under concurrent writers the
/// interleaving of CAS updates is schedule-dependent — fine for live
/// serving, where the scale is a smoothed host property, and absent by
/// construction in the byte-gated replay path (serial).
#[derive(Debug, Default)]
pub struct WallScale {
    ns_per_cycle_bits: AtomicU64,
    samples: AtomicU64,
}

impl WallScale {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observed ns-per-cycle ratio in; returns the
    /// post-update `(scale, samples)` pair.
    fn observe(&self, ratio: f64) -> (f64, u64) {
        let slot = self.samples.fetch_add(1, Ordering::SeqCst);
        if slot == 0 {
            self.ns_per_cycle_bits.store(ratio.to_bits(), Ordering::SeqCst);
        } else {
            let _ = self.ns_per_cycle_bits.fetch_update(
                Ordering::SeqCst,
                Ordering::SeqCst,
                |bits| {
                    let current = f64::from_bits(bits);
                    Some((current + WALL_SCALE_ALPHA * (ratio - current)).to_bits())
                },
            );
        }
        (self.ns_per_cycle(), slot + 1)
    }

    /// Current scale in nanoseconds per estimated cycle (0.0 before
    /// the first observation — the zero bit pattern is f64 0.0).
    pub fn ns_per_cycle(&self) -> f64 {
        f64::from_bits(self.ns_per_cycle_bits.load(Ordering::SeqCst))
    }

    /// Raw wall measurements folded in so far.
    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::SeqCst)
    }
}

impl Default for WallFeedback {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_ALPHA, DEFAULT_CALIBRATION_CAPACITY)
    }
}

impl WallFeedback {
    /// A wall feedback whose inner calibration uses `alpha` smoothing
    /// and at most `capacity` (backend, geometry-bucket) factors.
    pub fn with_capacity(alpha: f64, capacity: usize) -> Self {
        Self::with_shared_scale(alpha, capacity, Arc::new(WallScale::new()))
    }

    /// A wall feedback owning its own calibration but sharing the
    /// host-scale EWMA with other feedbacks. The sharded coordinator
    /// gives every shard a private wall-fed calibration (its factors
    /// are geometry-keyed, and geometries are shard-affine) while all
    /// shards train the one host scale — so warm-up is paid once per
    /// process and every shard normalizes against the same units.
    pub fn with_shared_scale(alpha: f64, capacity: usize, scale: Arc<WallScale>) -> Self {
        Self {
            calibration: Calibration::with_capacity(alpha, capacity),
            scale,
            fed: AtomicU64::new(0),
            roofline_gflops_bits: AtomicU64::new(0),
            roofline_gbps_bits: AtomicU64::new(0),
            roofline_violations: AtomicU64::new(0),
        }
    }

    /// The shared host-scale handle (to thread into sibling shards).
    pub fn shared_scale(&self) -> Arc<WallScale> {
        self.scale.clone()
    }

    /// Feed one measured kernel execution: `estimated` is the plan's
    /// raw cycle estimate for the executed geometry, `wall` the
    /// measured kernel time. Returns `true` once the observation
    /// actually reached the calibration (scale warm, inputs sane).
    /// Single-threaded floor semantics; a caller whose kernels run on
    /// the parallel pool should use [`Self::observe_wall_at`].
    pub fn observe_wall(
        &self,
        kind: BackendKind,
        job: &JobSpec,
        estimated: u64,
        wall: std::time::Duration,
    ) -> bool {
        self.observe_wall_at(kind, job, estimated, wall, 1)
    }

    /// [`Self::observe_wall`] with an explicit kernel thread budget:
    /// the physical floor a sample is clamped against is the
    /// [`Self::roofline_floor_ns_at`] for that budget, so a
    /// legitimately parallel wall (compute term divided across
    /// threads) is not miscounted as a roofline violation.
    pub fn observe_wall_at(
        &self,
        kind: BackendKind,
        job: &JobSpec,
        estimated: u64,
        wall: std::time::Duration,
        threads: usize,
    ) -> bool {
        let mut wall_ns = wall.as_secs_f64() * 1e9;
        if estimated == 0 || wall_ns <= 0.0 {
            return false;
        }
        if let Some(floor) = self.roofline_floor_ns_at(kind, job, threads) {
            if wall_ns < floor {
                self.roofline_violations.fetch_add(1, Ordering::Relaxed);
                // Floor the sample to the physical minimum: the
                // violation is counted for diagnostics, but the EWMA
                // must not learn from a wall the machine cannot
                // produce.
                wall_ns = floor;
            }
        }
        let ratio = wall_ns / estimated as f64;
        let (scale, samples) = self.scale.observe(ratio);
        if samples <= WALL_WARMUP_OBSERVATIONS || scale <= 0.0 {
            return false;
        }
        let observed_equiv = ((wall_ns / scale).round() as u64).max(1);
        self.calibration.observe(kind, job, estimated, observed_equiv);
        self.fed.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// The wall-fed calibration, to hand to the resolver in place of
    /// the simulated-cycle one.
    pub fn calibration(&self) -> &Calibration {
        &self.calibration
    }

    /// The current host scale in nanoseconds per estimated cycle (0.0
    /// before the first observation).
    pub fn ns_per_cycle(&self) -> f64 {
        self.scale.ns_per_cycle()
    }

    /// Raw wall measurements seen (including warm-up samples that were
    /// not yet fed through).
    pub fn scale_samples(&self) -> u64 {
        self.scale.samples()
    }

    /// Normalized observations actually fed into the calibration.
    pub fn observations(&self) -> u64 {
        self.fed.load(Ordering::Relaxed)
    }

    /// Arm a measured machine roofline as the physical floor under
    /// every subsequent wall observation (see the type docs). Re-arm
    /// freely; the latest peaks win.
    pub fn arm_roofline(&self, machine: &crate::kernels::MachineRoofline) {
        self.roofline_gflops_bits.store(machine.peak_gflops.to_bits(), Ordering::SeqCst);
        self.roofline_gbps_bits.store(machine.peak_gbps.to_bits(), Ordering::SeqCst);
    }

    /// The minimum physically plausible wall time in nanoseconds for
    /// `job` on `kind`, from the armed roofline and the compulsory
    /// traffic model (`crate::kernels::roofline`): the larger of
    /// flops at peak FLOP rate and bytes at peak bandwidth (GFLOP/s
    /// is flop/ns and GB/s is byte/ns, so both terms are already ns).
    /// `None` when unarmed, for the GPU backend (simulated, not a
    /// host kernel), or for degenerate geometry. The sparse backends'
    /// block count is estimated as `density * mb * kb` — the same
    /// expectation the pattern generators target. Single-threaded
    /// floor; see [`Self::roofline_floor_ns_at`].
    pub fn roofline_floor_ns(&self, kind: BackendKind, job: &JobSpec) -> Option<f64> {
        self.roofline_floor_ns_at(kind, job, 1)
    }

    /// [`Self::roofline_floor_ns`] for a kernel running with `threads`
    /// workers: when the job clears the parallel engagement floor
    /// ([`crate::kernels::parallel_engages`]) the compute term scales
    /// down by the thread count (each thread owns a row slice of the
    /// FLOPs); the bandwidth term stays whole — the memory bus is
    /// shared, extra threads do not add bytes per second to a
    /// bandwidth-bound kernel's ceiling. Below the engagement floor
    /// the kernel runs single-threaded and the floor is unchanged.
    pub fn roofline_floor_ns_at(
        &self,
        kind: BackendKind,
        job: &JobSpec,
        threads: usize,
    ) -> Option<f64> {
        use crate::kernels::roofline::{dense_traffic, nm_traffic, spmm_traffic};
        let gflops = f64::from_bits(self.roofline_gflops_bits.load(Ordering::SeqCst));
        let gbps = f64::from_bits(self.roofline_gbps_bits.load(Ordering::SeqCst));
        if gflops <= 0.0 || gbps <= 0.0 || job.b == 0 {
            return None;
        }
        let traffic = match kind {
            BackendKind::Dense => dense_traffic(job.m, job.k, job.n, job.dtype),
            BackendKind::Static | BackendKind::Dynamic => {
                let blocks = (job.m / job.b) * (job.k / job.b);
                let nnzb = (job.density * blocks as f64).round() as usize;
                spmm_traffic(job.m, job.k, job.n, job.b, nnzb, job.dtype)
            }
            BackendKind::Nm => {
                let (nm_n, nm_m) = crate::kernels::nm_for_density(job.density)?;
                if job.k % nm_m != 0 {
                    return None;
                }
                nm_traffic(job.m, job.k, job.n, nm_n, nm_m, job.dtype)
            }
            BackendKind::Gpu => return None,
        };
        let compute_scale =
            if crate::kernels::parallel_engages(job.dtype, traffic.flops, threads) {
                threads as f64
            } else {
                1.0
            };
        Some((traffic.flops / gflops / compute_scale).max(traffic.bytes / gbps))
    }

    /// Wall observations that undercut the armed roofline floor (0
    /// while unarmed). A nonzero count on a healthy host means the
    /// measurement plumbing or the traffic model is lying — surfaced
    /// for diagnostics, never gated.
    pub fn roofline_violations(&self) -> u64 {
        self.roofline_violations.load(Ordering::Relaxed)
    }
}

/// The amortized static-replan surcharge for scoring `estimates` at
/// `job`'s pattern family: static's *corrected* per-batch estimate
/// times the replan factor over the expected pattern lifetime. Zero
/// when there is no static candidate, no churn tracker, or no
/// observed churn. Both
/// [`ModeSelector::choose_workload`](crate::engine::ModeSelector::choose_workload)
/// and [`PlanCache::resolve_batch_with`](crate::coordinator::PlanCache::resolve_batch_with)
/// compute their surcharge here, so workload scoring cannot drift
/// between the two paths.
pub fn static_surcharge_for(
    estimates: &[PlanEstimate],
    calibration: Option<&Calibration>,
    job: &JobSpec,
    churn: Option<&crate::engine::ChurnTracker>,
) -> u64 {
    let Some(churn) = churn else { return 0 };
    let Some(st) = estimates.iter().find(|e| e.kind == BackendKind::Static) else {
        return 0;
    };
    let corrected = match calibration {
        Some(c) => c.correct(BackendKind::Static, job, st.cycles),
        None => st.cycles,
    };
    churn.static_surcharge(job, corrected)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Mode;

    fn job(m: usize, n: usize, density: f64) -> JobSpec {
        JobSpec {
            mode: Mode::Auto,
            m,
            k: m,
            n,
            b: 16,
            density,
            dtype: DType::Fp16,
            pattern_seed: 0,
        }
    }

    #[test]
    fn identity_observations_are_a_noop() {
        let cal = Calibration::default();
        let j = job(1024, 256, 1.0 / 16.0);
        for est in [100u64, 5_000, 123_456] {
            cal.observe(BackendKind::Static, &j, est, est);
        }
        assert_eq!(cal.factor(BackendKind::Static, &j), 1.0);
        assert_eq!(cal.correct(BackendKind::Static, &j, 777), 777);
        assert_eq!(cal.observations(), 3);
        assert_eq!(cal.geometry_stamp(&j), 0, "identity observations are not informative");
    }

    #[test]
    fn factors_move_toward_observed_ratio_and_clamp() {
        let cal = Calibration::new(0.5);
        let j = job(1024, 256, 1.0 / 16.0);
        cal.observe(BackendKind::Dynamic, &j, 1000, 2000); // ratio 2.0
        let f1 = cal.factor(BackendKind::Dynamic, &j);
        assert!(f1 > 1.0 && f1 <= 2.0, "factor {f1}");
        // Saturating in one direction must clamp at MAX_CORRECTION.
        for _ in 0..64 {
            cal.observe(BackendKind::Dynamic, &j, 1, u64::MAX / 2);
        }
        assert!(cal.factor(BackendKind::Dynamic, &j) <= MAX_CORRECTION);
        // Other backends and geometries are untouched.
        assert_eq!(cal.factor(BackendKind::Static, &j), 1.0);
        assert_eq!(cal.factor(BackendKind::Dynamic, &job(4096, 256, 1.0 / 16.0)), 1.0);
    }

    #[test]
    fn buckets_are_coarse_but_separate_backends() {
        let a = job(1024, 256, 1.0 / 16.0);
        let mut b = a.clone();
        b.pattern_seed = 99; // seed never affects the bucket
        assert_eq!(BucketKey::of(BackendKind::Static, &a), BucketKey::of(BackendKind::Static, &b));
        assert_ne!(
            BucketKey::of(BackendKind::Static, &a),
            BucketKey::of(BackendKind::Dynamic, &a)
        );
        // Same power-of-two decade buckets together; different decades apart.
        let mut c = a.clone();
        c.n = 300; // still log2 = 8
        assert_eq!(BucketKey::of(BackendKind::Static, &a), BucketKey::of(BackendKind::Static, &c));
        c.n = 1024;
        assert_ne!(BucketKey::of(BackendKind::Static, &a), BucketKey::of(BackendKind::Static, &c));
    }

    #[test]
    fn geometry_stamp_counts_informative_observations_per_geometry() {
        let cal = Calibration::default();
        let j = job(512, 128, 0.25);
        let other = job(2048, 512, 0.0625);
        assert_eq!(cal.geometry_stamp(&j), 0);
        // Identity observations never advance the stamp...
        for _ in 0..4 * OBSERVATIONS_PER_REVISIT {
            cal.observe(BackendKind::Dense, &j, 10, 10);
        }
        assert_eq!(cal.geometry_stamp(&j), 0);
        // ...informative ones (ratio 1.2) do, summed across the
        // backends the geometry's decision depends on.
        for _ in 0..3 {
            cal.observe(BackendKind::Dense, &j, 10, 12);
        }
        cal.observe(BackendKind::Dynamic, &j, 10, 15);
        assert_eq!(cal.geometry_stamp(&j), 4);
        // Unrelated geometries are untouched: their memoized
        // decisions must not churn on this stream.
        assert_eq!(cal.geometry_stamp(&other), 0);
        // Un-learning is informative too: an identity observation at a
        // bucket that has learned a correction disagrees with the
        // current factor, so it must advance the stamp (decisions can
        // swing back when the workload reverts).
        let learned = cal.geometry_stamp(&j);
        cal.observe(BackendKind::Dynamic, &j, 10, 10);
        assert_eq!(cal.geometry_stamp(&j), learned + 1);
    }

    #[test]
    fn amortized_argmin_shifts_static_but_reports_execution_estimates() {
        let j = job(1024, 256, 1.0 / 16.0);
        let est = |kind, cycles| PlanEstimate { kind, cycles, tflops: 1.0, propagation_steps: 0 };
        let estimates = vec![
            est(BackendKind::Dense, 4000),
            est(BackendKind::Static, 1000),
            est(BackendKind::Dynamic, 2500),
        ];
        // Zero surcharge: exactly the plain corrected argmin.
        let (win, c) = corrected_argmin_amortized(&estimates, None, &j, 0).unwrap();
        assert_eq!((win.kind, c), (BackendKind::Static, 1000));
        // A surcharge below the gap leaves static the winner...
        let (win, _) = corrected_argmin_amortized(&estimates, None, &j, 1000).unwrap();
        assert_eq!(win.kind, BackendKind::Static);
        // ...past the gap the argmin shifts to dynamic, and the
        // reported corrected value is dynamic's execution estimate
        // (never a surcharged score).
        let (win, c) = corrected_argmin_amortized(&estimates, None, &j, 2000).unwrap();
        assert_eq!((win.kind, c), (BackendKind::Dynamic, 2500));
    }

    #[test]
    fn roofline_floor_counts_impossible_walls() {
        let fb = WallFeedback::default();
        let j = job(256, 64, 1.0 / 16.0);
        // Unarmed: no floor, nothing counted.
        assert!(fb.roofline_floor_ns(BackendKind::Static, &j).is_none());
        fb.arm_roofline(&crate::kernels::MachineRoofline {
            peak_gflops: 100.0,
            peak_gbps: 50.0,
            tier: "test",
        });
        // Hand check: 16 expected blocks (256 of 1/16 density), f16:
        // flops = 2 * 16 * 256 * 64 = 524288 at 100 flop/ns, bytes =
        // 73860 at 50 B/ns -> the compute term binds, ~5243 ns.
        let floor = fb.roofline_floor_ns(BackendKind::Static, &j).unwrap();
        assert!((floor - 5242.88).abs() < 1.0, "floor {floor}");
        // The GPU backend is simulated, never floored.
        assert!(fb.roofline_floor_ns(BackendKind::Gpu, &j).is_none());
        // A wall below the physical floor is counted as a violation; a
        // plausible wall is not — and both still feed the scale.
        let fast = std::time::Duration::from_nanos((floor * 0.01) as u64);
        let slow = std::time::Duration::from_secs_f64(floor * 10.0 / 1e9);
        fb.observe_wall(BackendKind::Static, &j, 1000, fast);
        assert_eq!(fb.roofline_violations(), 1);
        fb.observe_wall(BackendKind::Static, &j, 1000, slow);
        assert_eq!(fb.roofline_violations(), 1);
        assert_eq!(fb.scale_samples(), 2);
    }

    #[test]
    fn clamped_absurd_walls_cannot_flip_the_argmin() {
        use std::time::Duration;
        // The flooring property: a physically-impossible wall (below
        // the armed roofline floor) is counted as a violation AND fed
        // at the floored value, so a glitched timer cannot teach a
        // backend a factor the machine cannot produce and hand it the
        // argmin.
        let wf = WallFeedback::default();
        let j = job(256, 64, 1.0 / 16.0);
        wf.arm_roofline(&crate::kernels::MachineRoofline {
            peak_gflops: 100.0,
            peak_gbps: 50.0,
            tier: "test",
        });
        let floor = wf.roofline_floor_ns(BackendKind::Dynamic, &j).unwrap();
        // Honest host at ~1 ns per estimated cycle, both contenders
        // running right at the physical floor through warm-up.
        let est_cycles = floor.round() as u64;
        let honest = Duration::from_secs_f64(floor * 1.02 / 1e9);
        for _ in 0..=WALL_WARMUP_OBSERVATIONS {
            wf.observe_wall(BackendKind::Static, &j, est_cycles, honest);
            wf.observe_wall(BackendKind::Dynamic, &j, est_cycles, honest);
        }
        assert_eq!(wf.roofline_violations(), 0, "honest walls sit above the floor");
        let est = |kind, cycles| PlanEstimate { kind, cycles, tflops: 1.0, propagation_steps: 0 };
        let estimates = vec![est(BackendKind::Static, 1000), est(BackendKind::Dynamic, 1010)];
        let (win, _) = corrected_argmin(&estimates, Some(wf.calibration()), &j).unwrap();
        assert_eq!(win.kind, BackendKind::Static, "premise: static wins before the glitch");
        // A burst of absurd sub-floor walls for dynamic: every one is
        // counted...
        for _ in 0..32 {
            wf.observe_wall(BackendKind::Dynamic, &j, est_cycles, Duration::from_nanos(1));
        }
        assert_eq!(wf.roofline_violations(), 32);
        // ...and every one is floored. Unclamped, the ~0.0002 ratio
        // would drive dynamic's factor to the lower MAX_CORRECTION
        // clamp (1/4) and flip the argmin on measurements the machine
        // cannot make; floored, the stream reads ~identity.
        let f_dyn = wf.calibration().factor(BackendKind::Dynamic, &j);
        assert!((f_dyn - 1.0).abs() < 0.1, "floored stream stays ~identity, got {f_dyn}");
        let (win, _) = corrected_argmin(&estimates, Some(wf.calibration()), &j).unwrap();
        assert_eq!(win.kind, BackendKind::Static, "absurd walls must not flip the argmin");
    }

    #[test]
    fn static_surcharge_helper_requires_churn_and_a_static_candidate() {
        use crate::engine::churn::ChurnTracker;
        let j = job(1024, 256, 1.0 / 16.0);
        let est = |kind, cycles| PlanEstimate { kind, cycles, tflops: 1.0, propagation_steps: 0 };
        let estimates = vec![est(BackendKind::Dense, 4000), est(BackendKind::Static, 1000)];
        assert_eq!(static_surcharge_for(&estimates, None, &j, None), 0);
        let churned = ChurnTracker::default();
        for seed in 0..64u64 {
            let mut f = j.clone();
            f.pattern_seed = seed;
            churned.observe(&f);
        }
        let s = static_surcharge_for(&estimates, None, &j, Some(&churned));
        assert!(s > 0, "observed churn must surcharge the static candidate");
        // No static candidate: nothing to amortize.
        let dense_only = vec![est(BackendKind::Dense, 4000)];
        assert_eq!(static_surcharge_for(&dense_only, None, &j, Some(&churned)), 0);
    }

    #[test]
    fn wall_feedback_warms_up_then_normalizes_units() {
        use std::time::Duration;
        let wf = WallFeedback::default();
        let j = job(1024, 256, 1.0 / 16.0);
        // Warm-up: uniform 1 ns/cycle across backends — nothing feeds
        // until the scale has settled.
        let mut fed_during_warmup = false;
        for i in 0..WALL_WARMUP_OBSERVATIONS {
            let kind = if i % 2 == 0 { BackendKind::Dense } else { BackendKind::Static };
            fed_during_warmup |=
                wf.observe_wall(kind, &j, 1_000, Duration::from_micros(1));
        }
        assert!(!fed_during_warmup, "warm-up samples must not feed the calibration");
        assert_eq!(wf.observations(), 0);
        assert!((wf.ns_per_cycle() - 1.0).abs() < 1e-9, "uniform stream settles the scale");
        // Post-warmup, a backend matching the fleet scale observes
        // ~identity: no factor learned.
        assert!(wf.observe_wall(BackendKind::Dense, &j, 1_000, Duration::from_micros(1)));
        assert!((wf.calibration().factor(BackendKind::Dense, &j) - 1.0).abs() < 0.05);
        // Degenerate inputs are ignored.
        assert!(!wf.observe_wall(BackendKind::Dense, &j, 0, Duration::from_micros(1)));
        assert!(!wf.observe_wall(BackendKind::Dense, &j, 1_000, Duration::ZERO));
    }

    #[test]
    fn zero_duration_walls_never_touch_the_scale() {
        use std::time::Duration;
        // A timer glitch (or a kernel cheap beyond the clock's
        // resolution) reports a zero wall. It must be dropped before
        // the scale EWMA: a 0-ns sample would crater ns_per_cycle and
        // every subsequent normalization would divide by a poisoned
        // scale.
        let wf = WallFeedback::default();
        let j = job(1024, 256, 1.0 / 16.0);
        for _ in 0..4 {
            assert!(!wf.observe_wall(BackendKind::Dense, &j, 1_000, Duration::ZERO));
        }
        assert_eq!(wf.scale_samples(), 0, "zero walls must not advance the warm-up");
        assert_eq!(wf.ns_per_cycle(), 0.0);
        assert_eq!(wf.observations(), 0);
        // Same for a zero estimate — there is no cycle axis to
        // normalize against.
        assert!(!wf.observe_wall(BackendKind::Dense, &j, 0, Duration::from_micros(1)));
        assert_eq!(wf.scale_samples(), 0);
        // A sane observation afterwards seeds the scale exactly to its
        // own ratio — no trace of the rejected samples.
        assert!(!wf.observe_wall(BackendKind::Dense, &j, 1_000, Duration::from_micros(2)));
        assert_eq!(wf.scale_samples(), 1);
        assert!((wf.ns_per_cycle() - 2.0).abs() < 1e-9, "first sample seeds, not averages");
    }

    #[test]
    fn warmup_gate_opens_exactly_after_the_threshold() {
        use std::time::Duration;
        let wf = WallFeedback::default();
        let j = job(1024, 256, 1.0 / 16.0);
        // Samples 1..=WALL_WARMUP_OBSERVATIONS are gated — including
        // the boundary sample itself (`samples <= WARMUP` rejects it).
        for i in 1..=WALL_WARMUP_OBSERVATIONS {
            let fed = wf.observe_wall(BackendKind::Dense, &j, 1_000, Duration::from_micros(1));
            assert!(!fed, "sample {i} is still warm-up");
            assert_eq!(wf.scale_samples(), i, "gated samples still train the scale");
        }
        assert_eq!(wf.observations(), 0);
        // The very next sample is the first to feed through.
        assert!(wf.observe_wall(BackendKind::Dense, &j, 1_000, Duration::from_micros(1)));
        assert_eq!(wf.scale_samples(), WALL_WARMUP_OBSERVATIONS + 1);
        assert_eq!(wf.observations(), 1);
    }

    #[test]
    fn wall_fed_calibration_flips_a_skewed_argmin() {
        use std::time::Duration;
        // The acceptance property: measured wall times, fed through
        // the units layer, demonstrably shift an auto-mode decision.
        // Workload: dynamic is the raw argmin by a sliver, but its
        // kernels measure ~3x slower per estimated cycle than the
        // dense/static fleet (a skewed pattern paying real propagation
        // cost the model missed).
        let wf = WallFeedback::default();
        let j = job(1024, 256, 1.0 / 16.0);
        let est = |kind, cycles| PlanEstimate { kind, cycles, tflops: 1.0, propagation_steps: 0 };
        let estimates = vec![
            est(BackendKind::Dense, 4000),
            est(BackendKind::Static, 1050),
            est(BackendKind::Dynamic, 1000),
        ];
        let (raw, _) = corrected_argmin(&estimates, None, &j).unwrap();
        assert_eq!(raw.kind, BackendKind::Dynamic, "premise: dynamic wins raw");
        // Mixed measured stream: 1 ns/cycle for dense and static, 3
        // ns/cycle for dynamic.
        for _ in 0..32 {
            wf.observe_wall(BackendKind::Dense, &j, 4000, Duration::from_nanos(4000));
            wf.observe_wall(BackendKind::Static, &j, 1050, Duration::from_nanos(1050));
            wf.observe_wall(BackendKind::Dynamic, &j, 1000, Duration::from_nanos(3000));
        }
        assert!(wf.observations() > 0, "post-warmup observations fed through");
        // The learned factors are relative to the traffic-wide scale:
        // dynamic's must sit clearly above the dense/static ones.
        let f_dyn = wf.calibration().factor(BackendKind::Dynamic, &j);
        let f_st = wf.calibration().factor(BackendKind::Static, &j);
        assert!(f_dyn > f_st * 1.5, "dynamic {f_dyn} vs static {f_st}");
        // And the argmin flips to static under the wall-fed
        // calibration — the measured-reality dispatch shift.
        let (win, _) = corrected_argmin(&estimates, Some(wf.calibration()), &j).unwrap();
        assert_eq!(win.kind, BackendKind::Static, "wall feedback must flip the argmin");
    }

    #[test]
    fn wall_feedback_is_invariant_to_absolute_host_speed() {
        use std::time::Duration;
        // Two hosts, one 10x slower across the board: the learned
        // factors must agree — absolute speed lands in the scale, not
        // the factors.
        let j = job(512, 128, 0.25);
        let factors_at = |ns_per_cycle: u64| {
            let wf = WallFeedback::default();
            for _ in 0..24 {
                wf.observe_wall(
                    BackendKind::Dense,
                    &j,
                    1_000,
                    Duration::from_nanos(1_000 * ns_per_cycle),
                );
                wf.observe_wall(
                    BackendKind::Dynamic,
                    &j,
                    1_000,
                    Duration::from_nanos(2_000 * ns_per_cycle),
                );
            }
            (
                wf.calibration().factor(BackendKind::Dense, &j),
                wf.calibration().factor(BackendKind::Dynamic, &j),
                wf.ns_per_cycle(),
            )
        };
        let (d1, dy1, s1) = factors_at(1);
        let (d10, dy10, s10) = factors_at(10);
        // The normalizer rounds equivalent cycles to integers, so the
        // two hosts can differ by a cycle here and there — but the
        // factors must agree far beyond the dense/dynamic gap.
        assert!((d1 - d10).abs() < 1e-2 && (dy1 - dy10).abs() < 1e-2);
        assert!(dy1 > d1, "the relatively slow backend learns the high factor");
        assert!(s10 > s1 * 5.0, "absolute speed lives in the scale");
    }

    #[test]
    fn shared_scale_trains_once_across_feedbacks() {
        use std::time::Duration;
        // Two shards sharing one WallScale: warm-up is paid once for
        // the process, and after it both shards' observations feed
        // their own calibrations against the same units.
        let scale = Arc::new(WallScale::new());
        let a = WallFeedback::with_shared_scale(DEFAULT_ALPHA, 64, scale.clone());
        let b = WallFeedback::with_shared_scale(DEFAULT_ALPHA, 64, scale.clone());
        let j = job(1024, 256, 1.0 / 16.0);
        for i in 0..WALL_WARMUP_OBSERVATIONS {
            let wf = if i % 2 == 0 { &a } else { &b };
            assert!(!wf.observe_wall(BackendKind::Dense, &j, 1_000, Duration::from_micros(1)));
        }
        assert_eq!(scale.samples(), WALL_WARMUP_OBSERVATIONS);
        assert_eq!(a.scale_samples(), b.scale_samples());
        // The next observation on *either* shard is past warm-up.
        assert!(b.observe_wall(BackendKind::Dense, &j, 1_000, Duration::from_micros(1)));
        assert_eq!(b.observations(), 1);
        assert_eq!(a.observations(), 0, "fed counts stay per-shard");
        // Calibrations are private: a's factors are untouched by b's.
        assert!(a.observe_wall(BackendKind::Dynamic, &j, 1_000, Duration::from_micros(3)));
        assert!(a.calibration().factor(BackendKind::Dynamic, &j) > 1.0);
        assert_eq!(b.calibration().factor(BackendKind::Dynamic, &j), 1.0);
    }

    #[test]
    fn poisoned_calibration_lock_recovers() {
        // A panicking worker holding the factor map must not make the
        // calibration unreadable for survivors (sharded-coordinator
        // panic isolation).
        let cal = Arc::new(Calibration::default());
        let j = job(1024, 256, 1.0 / 16.0);
        cal.observe(BackendKind::Dynamic, &j, 1_000, 2_000);
        let poisoner = cal.clone();
        let _ = std::thread::spawn(move || {
            let _g = poisoner.factors.lock().unwrap();
            panic!("injected");
        })
        .join();
        assert!(cal.factor(BackendKind::Dynamic, &j) > 1.0);
        assert_eq!(cal.buckets(), 1);
    }

    #[test]
    fn corrected_argmin_is_first_min_and_respects_factors() {
        let j = job(1024, 256, 1.0 / 16.0);
        let est = |kind, cycles| PlanEstimate { kind, cycles, tflops: 1.0, propagation_steps: 0 };
        let estimates = vec![
            est(BackendKind::Dense, 1000),
            est(BackendKind::Static, 800),
            est(BackendKind::Dynamic, 800),
        ];
        // No calibration: exact raw argmin, first of the tie wins.
        let (win, c) = corrected_argmin(&estimates, None, &j).unwrap();
        assert_eq!((win.kind, c), (BackendKind::Static, 800));
        // Penalize static hard enough and the argmin flips.
        let cal = Calibration::new(1.0);
        cal.observe(BackendKind::Static, &j, 1000, 2000);
        let (win, c) = corrected_argmin(&estimates, Some(&cal), &j).unwrap();
        assert_eq!(win.kind, BackendKind::Dynamic);
        assert_eq!(c, 800);
    }
}
