//! Dense matmul baseline on the IPU simulator (`poplin::matMul`
//! analogue): the denominator of every speedup in the paper.
//!
//! The planner searches 3-D partitions `(q_m, q_k, q_n)` of the
//! `m x k @ k x n` GEMM over the tile array, costing each candidate as
//! a BSP program (input exchange, AMP compute, output all-reduce) and
//! keeping the fastest memory-feasible plan.

use crate::error::{Error, Result};
use crate::sim::chip::{CostModel, IpuSpec};
use crate::sim::{compute, exchange, execute, Cost, MemoryPlan, Program, Superstep};
use crate::DType;

/// A chosen dense partition and its cost.
#[derive(Debug, Clone)]
pub struct DensePlan {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub dtype: DType,
    pub q_m: usize,
    pub q_k: usize,
    pub q_n: usize,
    pub program: Program,
    pub cost: Cost,
    pub memory: MemoryPlan,
}

impl DensePlan {
    /// Achieved TFLOP/s under the paper's convention (dense: d = 1).
    pub fn tflops(&self, spec: &IpuSpec) -> f64 {
        crate::tflops(
            crate::spmm_flops(self.m, self.k, self.n, 1.0),
            self.cost.total(),
            spec.clock_hz,
        )
    }
}

use crate::sim::chip::candidate_splits;

/// Build and cost the BSP program for one `(q_m, q_k, q_n)` candidate.
fn build_program(
    m: usize,
    k: usize,
    n: usize,
    dtype: DType,
    q: (usize, usize, usize),
    spec: &IpuSpec,
    cm: &CostModel,
) -> Result<(Program, Cost, MemoryPlan)> {
    let (q_m, q_k, q_n) = q;
    let tiles = q_m * q_k * q_n;
    if tiles > spec.tiles {
        return Err(Error::Plan(format!("{tiles} partitions exceed {} tiles", spec.tiles)));
    }
    let dsize = dtype.size();
    // Per-tile slab shapes (ceil so the worst tile is costed).
    let tm = m.div_ceil(q_m);
    let tk = k.div_ceil(q_k);
    let tn = n.div_ceil(q_n);

    // Memory. Chip level: one resident copy of each operand (SUMMA-
    // style staged broadcast keeps replication in *time*, through
    // bounded working buffers, not in storage) plus ≤ 2 live copies of
    // the output during the staged reduction.
    let mut mem = MemoryPlan::new();
    mem.alloc("a_total", m * k * dsize);
    mem.alloc("x_total", k * n * dsize);
    mem.alloc("y_partials", m * n * dsize * q_k.min(2));
    mem.check_chip(spec)?;
    // Per tile: the resident accumulator, the exclusive operand shares
    // and the streamed working chunks.
    let mut tile_mem = MemoryPlan::new();
    tile_mem.alloc("partials", tm * tn * dsize);
    tile_mem.alloc("a_share", (m * k * dsize).div_ceil(tiles));
    tile_mem.alloc("x_share", (k * n * dsize).div_ceil(tiles));
    tile_mem.alloc("working", 3 * 32 * 1024);
    tile_mem.check(spec)?;

    let mut prog = Program::new(tiles);
    // 1. Broadcast input slabs to tiles (A to the q_n group, X to the
    //    q_m group). Cost = worst-tile incoming bytes.
    prog.push(Superstep::exchange(
        "input-exchange",
        exchange::slab_bytes(tm, tk, dsize) + exchange::slab_bytes(tk, tn, dsize),
    ));
    // 2. On-tile AMP matmul.
    let macs = (tm as u64) * (tk as u64) * (tn as u64);
    prog.push(Superstep::compute(
        "matmul",
        compute::dense_matmul_cycles(macs, dtype, spec, cm),
    ));
    // 3. All-reduce partials over q_k.
    if q_k > 1 {
        let partial_elems = (tm as u64) * (tn as u64);
        let bytes = exchange::allreduce_bytes(partial_elems, q_k, dsize);
        let adds = partial_elems.div_ceil(q_k as u64) * (q_k as u64 - 1);
        prog.push(Superstep::mixed("reduce", compute::reduce_cycles(adds, cm), bytes));
    }
    let cost = execute(&prog, spec);
    Ok((prog, cost, mem))
}

/// Plan a dense matmul: search the partition space, return the best
/// memory-feasible plan.
pub fn plan(m: usize, k: usize, n: usize, dtype: DType, spec: &IpuSpec, cm: &CostModel) -> Result<DensePlan> {
    if m == 0 || k == 0 || n == 0 {
        return Err(Error::Plan("zero dimension".into()));
    }
    let mut best: Option<DensePlan> = None;
    let mut last_oom: Option<Error> = None;
    for &q_m in &candidate_splits(m, spec.tiles) {
        for &q_k in &candidate_splits(k, spec.tiles / q_m) {
            for &q_n in &candidate_splits(n, spec.tiles / (q_m * q_k)) {
                match build_program(m, k, n, dtype, (q_m, q_k, q_n), spec, cm) {
                    Ok((program, cost, memory)) => {
                        let better = best
                            .as_ref()
                            .map(|b| cost.total() < b.cost.total())
                            .unwrap_or(true);
                        if better {
                            best = Some(DensePlan {
                                m,
                                k,
                                n,
                                dtype,
                                q_m,
                                q_k,
                                q_n,
                                program,
                                cost,
                                memory,
                            });
                        }
                    }
                    Err(e @ Error::OutOfMemory { .. }) => last_oom = Some(e),
                    Err(_) => {}
                }
            }
        }
    }
    best.ok_or_else(|| {
        last_oom.unwrap_or_else(|| Error::Plan(format!("no feasible dense plan for {m}x{k}x{n}")))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (IpuSpec, CostModel) {
        (IpuSpec::default(), CostModel::default())
    }

    #[test]
    fn large_fp16_near_paper_throughput() {
        // Fig 2: IPU dense FP16 reaches ~200-270 TFLOP/s at large shapes.
        let (spec, cm) = env();
        let p = plan(4096, 4096, 16384, DType::Fp16, &spec, &cm).unwrap();
        let t = p.tflops(&spec);
        assert!((170.0..280.0).contains(&t), "got {t} TFLOP/s");
    }

    #[test]
    fn fp32_about_quarter_rate() {
        let (spec, cm) = env();
        let t16 = plan(4096, 4096, 8192, DType::Fp16, &spec, &cm).unwrap().tflops(&spec);
        let t32 = plan(4096, 4096, 8192, DType::Fp32, &spec, &cm).unwrap().tflops(&spec);
        let ratio = t16 / t32;
        assert!((2.0..5.0).contains(&ratio), "fp16/fp32 ratio {ratio}");
    }

    #[test]
    fn small_batch_degrades_gracefully() {
        // Fig 2: the IPU stays comparatively strong at low batch, but
        // throughput still drops.
        let (spec, cm) = env();
        let big = plan(4096, 4096, 8192, DType::Fp16, &spec, &cm).unwrap().tflops(&spec);
        let small = plan(4096, 4096, 16, DType::Fp16, &spec, &cm).unwrap().tflops(&spec);
        assert!(small < big);
        assert!(big / small < 100.0, "IPU low-batch penalty should be moderate");
    }

    #[test]
    fn oom_on_absurd_size() {
        // m=k=8192 n=65536 fp16: X alone is 1 GB > 900 MB SRAM.
        let (spec, cm) = env();
        match plan(8192, 8192, 65536, DType::Fp16, &spec, &cm) {
            Err(Error::OutOfMemory { .. }) => {}
            other => panic!("expected OOM, got {:?}", other.map(|p| p.tflops(&spec))),
        }
    }

    #[test]
    fn plan_respects_tile_budget() {
        let (spec, cm) = env();
        let p = plan(1024, 1024, 1024, DType::Fp16, &spec, &cm).unwrap();
        assert!(p.q_m * p.q_k * p.q_n <= spec.tiles);
        assert!(p.memory.check_chip(&spec).is_ok());
    }

    #[test]
    fn zero_dim_rejected() {
        let (spec, cm) = env();
        assert!(plan(0, 4, 4, DType::Fp16, &spec, &cm).is_err());
    }
}
