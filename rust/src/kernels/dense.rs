//! Tiled dense matmul: the `ikj` register-blocked GEMM the dense
//! numeric path runs on.
//!
//! The naive reference ([`crate::runtime::dense_ref`]) streams `y`
//! through memory once per `k` step; this kernel blocks `I_TILE`
//! output rows by [`N_TILE`](crate::kernels::N_TILE) output columns so
//! the accumulator panel stays in registers across the whole `k` loop
//! and each output element is written exactly once, into a
//! caller-owned (reusable) buffer. Like the SpMM kernels it is generic
//! over the storage element ([`Element`]): operands and outputs live
//! in the job's dtype, partial sums accumulate in f32 (the AMP
//! contract), and the output store quantizes once.
//!
//! SIMD: [`matmul`] first offers the whole call to the arch-gated
//! wide kernels in [`crate::kernels::simd`] (DESIGN.md §5.1); the
//! scalar loops here are the mandatory fallback, and the wide paths
//! are pinned bit-identical to them per dtype. [`matmul_scalar`]
//! bypasses dispatch for tests and differential harnesses.

use crate::error::{Error, Result};
use crate::kernels::element::Element;
use crate::kernels::parallel::{parallel_engages, with_merge_units};
use crate::kernels::pool::{self, SendPtr};
use crate::kernels::spmm::N_TILE;

/// Output-row tile height of the register panel.
pub const I_TILE: usize = 4;

fn check_operands<E: Element>(
    a: &[E],
    x: &[E],
    m: usize,
    k: usize,
    n: usize,
    y: &[E],
) -> Result<()> {
    if a.len() != m * k {
        return Err(Error::InvalidFormat(format!(
            "a has {} elements, kernel needs {m} x {k}",
            a.len()
        )));
    }
    if x.len() != k * n {
        return Err(Error::InvalidFormat(format!(
            "x has {} elements, kernel needs {k} x {n}",
            x.len()
        )));
    }
    if y.len() != m * n {
        return Err(Error::InvalidFormat(format!(
            "y has {} elements, kernel needs {m} x {n}",
            y.len()
        )));
    }
    Ok(())
}

/// Tiled dense matmul: `y = A x`, `a` row-major `m x k`, `x` row-major
/// `k x n`, `y` row-major `m x n`, all in storage type `E` with f32
/// accumulation. Overwrites all of `y`. Dispatches to the widest SIMD
/// tier the machine supports ([`crate::kernels::simd`]); the result
/// is bit-identical across tiers.
pub fn matmul<E: Element>(
    a: &[E],
    x: &[E],
    m: usize,
    k: usize,
    n: usize,
    y: &mut [E],
) -> Result<()> {
    check_operands(a, x, m, k, n, y)?;
    if crate::kernels::simd::try_matmul(a, x, m, k, n, y) {
        return Ok(());
    }
    matmul_rows_scalar(a, x, m, k, n, y);
    Ok(())
}

/// [`matmul`] pinned to the scalar fallback path, bypassing SIMD
/// dispatch; bit-identical to [`matmul`] on every machine (the
/// contract `tests/kernels_differential.rs` pins).
pub fn matmul_scalar<E: Element>(
    a: &[E],
    x: &[E],
    m: usize,
    k: usize,
    n: usize,
    y: &mut [E],
) -> Result<()> {
    check_operands(a, x, m, k, n, y)?;
    matmul_rows_scalar(a, x, m, k, n, y);
    Ok(())
}

/// Row-parallel dense matmul on the persistent kernel pool: output
/// rows are split into row-merge units (the shared partitioner of
/// [`crate::kernels::parallel`]; dense rows are uniform, so units are
/// equal row spans) and each unit runs the full kernel on its own
/// `a`-rows / `y`-panel sub-problem. Bit-identical to [`matmul`]:
/// every output row's f32 accumulation in `dense_tile` is independent
/// of which `I_TILE` group it lands in (the `l` loop order is the
/// row's own), so a sub-matmul over rows `r0..r1` produces exactly the
/// rows the full matmul would — and the SIMD tiers are pinned
/// bit-identical to the scalar body per dtype.
pub fn matmul_parallel<E: Element>(
    a: &[E],
    x: &[E],
    m: usize,
    k: usize,
    n: usize,
    y: &mut [E],
    threads: usize,
) -> Result<()> {
    check_operands(a, x, m, k, n, y)?;
    with_merge_units(m, m, |_| 1, threads, |units| {
        if units.len() <= 1 || threads <= 1 {
            if crate::kernels::simd::try_matmul(a, x, m, k, n, y) {
                return;
            }
            matmul_rows_scalar(a, x, m, k, n, y);
            return;
        }
        let base = SendPtr(y.as_mut_ptr());
        pool::global().run(units.len(), &|u| {
            let (r0, r1) = units[u];
            // SAFETY: units are disjoint contiguous spans of 0..m, so
            // each claimed unit writes a disjoint sub-slice of `y`;
            // the injector blocks until every unit completes.
            let panel =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * n), (r1 - r0) * n) };
            let rows = r1 - r0;
            let a_panel = &a[r0 * k..r1 * k];
            if !crate::kernels::simd::try_matmul(a_panel, x, rows, k, n, panel) {
                matmul_rows_scalar(a_panel, x, rows, k, n, panel);
            }
        });
    });
    Ok(())
}

/// Dense matmul with automatic parallelism: row-parallel on the pool
/// when `2·m·k·n` FLOPs clear the dtype-scaled engagement floor
/// ([`crate::kernels::parallel::parallel_engages`]), single-call
/// [`matmul`] otherwise; bit-identical either way.
pub fn matmul_auto<E: Element>(
    a: &[E],
    x: &[E],
    m: usize,
    k: usize,
    n: usize,
    y: &mut [E],
    threads: usize,
) -> Result<()> {
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    if parallel_engages(E::DTYPE, flops, threads) {
        matmul_parallel(a, x, m, k, n, y, threads)
    } else {
        matmul(a, x, m, k, n, y)
    }
}

fn matmul_rows_scalar<E: Element>(a: &[E], x: &[E], m: usize, k: usize, n: usize, y: &mut [E]) {
    let mut i0 = 0;
    while i0 < m {
        let ib = I_TILE.min(m - i0);
        let mut j = 0;
        while j < n {
            let tile = N_TILE.min(n - j);
            dense_tile::<E>(a, x, k, n, i0, ib, j, tile, y);
            j += tile;
        }
        i0 += ib;
    }
}

/// One `ib x tile` output tile (`ib <= I_TILE` rows from `i0`,
/// `tile <= N_TILE` batch columns from `j`) of the `ikj` kernel. Like
/// `spmm_tile_b` this single body serves the scalar path's full tiles
/// and remainders *and* the remainder path of every SIMD tier, so the
/// tiers' edge handling is the fallback's by construction.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dense_tile<E: Element>(
    a: &[E],
    x: &[E],
    k: usize,
    n: usize,
    i0: usize,
    ib: usize,
    j: usize,
    tile: usize,
    y: &mut [E],
) {
    let mut acc = [[0f32; N_TILE]; I_TILE];
    for l in 0..k {
        let xrow = &x[l * n + j..][..tile];
        let mut xf = [0f32; N_TILE];
        for (d, &s) in xf.iter_mut().zip(xrow) {
            *d = s.to_f32();
        }
        for (ii, acc_row) in acc.iter_mut().enumerate().take(ib) {
            let w = a[(i0 + ii) * k + l].to_f32();
            for (v, &xv) in acc_row.iter_mut().zip(&xf[..tile]) {
                *v += w * xv;
            }
        }
    }
    for (ii, acc_row) in acc.iter().enumerate().take(ib) {
        for (o, &v) in
            y[(i0 + ii) * n + j..(i0 + ii) * n + j + tile].iter_mut().zip(&acc_row[..tile])
        {
            *o = E::from_f32(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::element::{dequantize, quantize, F16};
    use crate::kernels::spmm::{close_enough, close_enough_for};
    use crate::util::Rng;
    use crate::DType;

    fn reference(a: &[f32], x: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0f32; m * n];
        for i in 0..m {
            for l in 0..k {
                for j in 0..n {
                    y[i * n + j] += a[i * k + l] * x[l * n + j];
                }
            }
        }
        y
    }

    #[test]
    fn matches_reference_including_remainders() {
        let mut rng = Rng::seed_from_u64(0xDE5E);
        // Shapes straddling both tile boundaries (m % I_TILE, n % N_TILE).
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (4, 8, 16), (5, 7, 17), (9, 3, 33)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let x: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mut y = vec![f32::NAN; m * n];
            matmul(&a, &x, m, k, n, &mut y).unwrap();
            let expect = reference(&a, &x, m, k, n);
            for (i, (&u, &v)) in y.iter().zip(&expect).enumerate() {
                assert!(close_enough(u, v), "m={m} k={k} n={n} elem {i}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn f16_matmul_matches_f32_oracle_on_quantized_operands() {
        let mut rng = Rng::seed_from_u64(0xDE16);
        let (m, k, n) = (9, 17, 33);
        let af: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let xf: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let a16: Vec<F16> = quantize(&af);
        let x16: Vec<F16> = quantize(&xf);
        let mut y16 = vec![F16(0x7E00); m * n];
        matmul(&a16, &x16, m, k, n, &mut y16).unwrap();
        let expect = reference(&dequantize(&a16), &dequantize(&x16), m, k, n);
        for (i, (&u, &v)) in dequantize(&y16).iter().zip(&expect).enumerate() {
            assert!(close_enough_for(DType::Fp16, u, v), "elem {i}: {u} vs {v}");
        }
    }

    #[test]
    fn dispatched_matmul_is_bit_identical_to_pinned_scalar() {
        let mut rng = Rng::seed_from_u64(0x51D2);
        let (m, k, n) = (9, 17, 33); // straddles both tile remainders
        let af: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let xf: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let (mut y, mut y_ref) = (vec![f32::NAN; m * n], vec![f32::NAN; m * n]);
        matmul(&af, &xf, m, k, n, &mut y).unwrap();
        matmul_scalar(&af, &xf, m, k, n, &mut y_ref).unwrap();
        for (i, (&u, &v)) in y.iter().zip(&y_ref).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "elem {i}: {u} vs {v}");
        }
        let (a16, x16) = (quantize::<F16>(&af), quantize::<F16>(&xf));
        let (mut y16, mut y16_ref) = (vec![F16(0x7E00); m * n], vec![F16(0x7E00); m * n]);
        matmul(&a16, &x16, m, k, n, &mut y16).unwrap();
        matmul_scalar(&a16, &x16, m, k, n, &mut y16_ref).unwrap();
        for (i, (&u, &v)) in y16.iter().zip(&y16_ref).enumerate() {
            assert_eq!(u.0, v.0, "f16 elem {i}");
        }
    }

    #[test]
    fn shape_errors_not_panics() {
        let mut y = vec![0f32; 4];
        assert!(matmul(&[0.0; 3], &[0.0; 4], 2, 2, 2, &mut y).is_err());
        assert!(matmul(&[0.0; 4], &[0.0; 3], 2, 2, 2, &mut y).is_err());
        assert!(matmul(&[0.0; 4], &[0.0; 4], 2, 2, 2, &mut y[..3]).is_err());
        assert!(matmul_scalar(&[0.0; 4], &[0.0; 3], 2, 2, 2, &mut y).is_err());
        assert!(matmul_parallel(&[0.0; 4], &[0.0; 3], 2, 2, 2, &mut y, 4).is_err());
        assert!(matmul_auto(&[0.0; 3], &[0.0; 4], 2, 2, 2, &mut y, 4).is_err());
    }

    #[test]
    fn parallel_matmul_is_bit_identical_to_single_call() {
        let mut rng = Rng::seed_from_u64(0xDEA1);
        // Row counts straddling unit boundaries, odd n remainders.
        for &(m, k, n) in &[(9usize, 17usize, 33usize), (64, 32, 21), (3, 5, 7)] {
            let af: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let xf: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let (mut y, mut yp) = (vec![f32::NAN; m * n], vec![f32::NAN; m * n]);
            matmul(&af, &xf, m, k, n, &mut y).unwrap();
            matmul_parallel(&af, &xf, m, k, n, &mut yp, 4).unwrap();
            assert_eq!(y, yp, "m={m} k={k} n={n}");
            let (a16, x16) = (quantize::<F16>(&af), quantize::<F16>(&xf));
            let (mut y16, mut y16p) = (vec![F16(0x7E00); m * n], vec![F16(0x7E00); m * n]);
            matmul(&a16, &x16, m, k, n, &mut y16).unwrap();
            matmul_parallel(&a16, &x16, m, k, n, &mut y16p, 4).unwrap();
            assert_eq!(y16, y16p, "f16 m={m} k={k} n={n}");
        }
    }
}
