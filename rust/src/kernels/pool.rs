//! Persistent kernel worker pool: the execution vehicle behind every
//! panel-parallel kernel ([`spmm_parallel`], [`spmm_nm_parallel`],
//! [`matmul_parallel`]).
//!
//! The old dispatch spawned OS threads per call (`std::thread::scope`)
//! — tens of microseconds of spawn tax per kernel, the documented
//! reason the engagement floor sat at millions of FLOPs per thread.
//! This pool pays the spawn cost **once**, lazily, at first parallel
//! dispatch: `default_threads() - 1` workers are created and then
//! parked on a condvar. A steady-state dispatch ("injection") is a
//! mutex acquire, one raw-pointer store, and a condvar broadcast —
//! **no allocation and no thread spawn** (pinned by
//! `tests/hot_path_alloc.rs`).
//!
//! # Row-merge scheduling
//!
//! Callers pass a list of deterministic work units (nnz-balanced row
//! panels from [`partition_panels`], oversubscribed past the thread
//! count). Workers and the injecting thread claim units dynamically
//! through one atomic counter, so a worker that drew short rows
//! immediately merges into the remaining units instead of idling on
//! the skew tail (Gale et al.'s row-merge idea, applied at panel
//! granularity). Unit *boundaries* are a pure function of the operand
//! and the thread budget; only the unit→worker assignment is dynamic,
//! and every unit writes a disjoint output slice with the same
//! per-row kernel body — so outputs are bit-identical to the serial
//! kernel no matter which worker runs what (DESIGN.md §5.3).
//!
//! # Protocol
//!
//! One job is active at a time; concurrent injectors queue on the
//! completion condvar, so a parallel kernel always gets the whole
//! pool (sharded-coordinator workers injecting simultaneously
//! serialize here rather than oversubscribing the machine). The claim
//! counter is epoch-tagged (`epoch << 32 | next_unit` behind one CAS)
//! so a worker holding a stale job descriptor can never claim a unit
//! of the next job: the epoch check and the claim are one atomic
//! operation. A unit that panics poisons the job (the injector
//! re-panics after completion) but still counts toward the completion
//! latch, so the pool survives and no thread deadlocks.
//!
//! [`spmm_parallel`]: crate::kernels::spmm_parallel
//! [`spmm_nm_parallel`]: crate::kernels::nm::spmm_nm_parallel
//! [`matmul_parallel`]: crate::kernels::dense::matmul_parallel
//! [`partition_panels`]: crate::kernels::partition_panels

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

use crate::kernels::parallel::default_threads;

/// Type-erased pointer to the injector's task closure. The injector
/// blocks in [`KernelPool::run`] until every unit completes, so the
/// pointee outlives every dereference; workers only dereference after
/// an epoch-checked claim (see the module doc).
#[derive(Clone, Copy, Debug)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared calls from many threads are
// fine) and the injector keeps it alive for the job's whole lifetime.
unsafe impl Send for TaskPtr {}

/// Raw output-buffer cursor the panel closures capture so disjoint
/// slices can be re-derived per claimed unit. Soundness is the
/// caller's obligation: units must map to non-overlapping ranges.
pub(crate) struct SendPtr<T>(pub *mut T);
// SAFETY: carries a raw pointer across threads; every user writes
// only the disjoint per-unit range it claimed.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// The active injected job: the erased task, its unit count, and the
/// epoch tag its claims are validated against.
#[derive(Clone, Copy, Debug)]
struct Job {
    task: TaskPtr,
    units: u32,
    epoch: u32,
}

#[derive(Debug, Default)]
struct State {
    job: Option<Job>,
    epoch: u32,
    shutdown: bool,
}

#[derive(Debug, Default)]
struct Shared {
    state: Mutex<State>,
    /// Workers park here; signaled on injection (and shutdown).
    work_cv: Condvar,
    /// Injectors park here, both for their own job's completion and
    /// for the single job slot to free up.
    done_cv: Condvar,
    /// `epoch << 32 | next_unclaimed_unit` — the row-merge claim
    /// cursor. CAS-incremented so the epoch check and the claim are
    /// one atomic step.
    claim: AtomicU64,
    /// Units completed for the active epoch (the completion latch).
    done: AtomicU64,
    /// A unit panicked; the injector re-panics once the job drains.
    poisoned: AtomicBool,
    spawns: AtomicU64,
    injects: AtomicU64,
    steals: AtomicU64,
}

fn lock(m: &Mutex<State>) -> MutexGuard<'_, State> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn pack(epoch: u32, unit: u32) -> u64 {
    (u64::from(epoch) << 32) | u64::from(unit)
}

fn epoch_of(packed: u64) -> u32 {
    (packed >> 32) as u32
}

fn unit_of(packed: u64) -> u32 {
    packed as u32
}

/// Observability counters of a pool (or of [`global`] via
/// [`counters`]). `spawns` moves only while the pool warms up;
/// `contention.rs` and the CI contention job assert it stays flat in
/// steady state. `injects` counts parallel dispatches, `steals` the
/// work units executed by parked workers rather than the injecting
/// thread (the row-merge signal: a skew tail being absorbed shows up
/// as steals).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolCounters {
    pub spawns: u64,
    pub injects: u64,
    pub steals: u64,
}

/// A persistent, parked worker pool (see the module doc). `Drop`
/// shuts the workers down and joins them; the process-wide [`global`]
/// pool is never dropped.
#[derive(Debug)]
pub struct KernelPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl KernelPool {
    /// A pool with `workers` parked helper threads. The injecting
    /// thread always executes units too, so effective parallelism is
    /// `workers + 1` and `workers = 0` degenerates to serial
    /// execution in the caller.
    pub fn with_workers(workers: usize) -> Self {
        let shared = Arc::new(Shared::default());
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let s = Arc::clone(&shared);
            let h = std::thread::Builder::new()
                .name(format!("popsparse-pool-{i}"))
                .spawn(move || worker_loop(s))
                .expect("spawn kernel pool worker");
            shared.spawns.fetch_add(1, Ordering::Relaxed);
            handles.push(h);
        }
        Self { shared, handles }
    }

    /// Effective parallelism: parked workers plus the injecting
    /// thread.
    pub fn parallelism(&self) -> usize {
        self.handles.len() + 1
    }

    /// Current counter values (monotonic over the pool's lifetime).
    pub fn counters(&self) -> PoolCounters {
        PoolCounters {
            spawns: self.shared.spawns.load(Ordering::Relaxed),
            injects: self.shared.injects.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
        }
    }

    /// Execute `f(0..units)` across the pool and the calling thread,
    /// returning once every unit completed. Units are claimed
    /// dynamically (row-merge); `f` must confine each unit's writes
    /// to disjoint state. Steady-state cost: no allocation, no thread
    /// spawn. Panics (after draining the job) if any unit panicked.
    ///
    /// Must not be called from inside a pool task (a nested injection
    /// would wait on its own job's slot); the kernel layer never
    /// nests parallel dispatches.
    pub fn run(&self, units: usize, f: &(dyn Fn(usize) + Sync)) {
        if units == 0 {
            return;
        }
        debug_assert!(units <= u32::MAX as usize, "unit count overflows the claim word");
        // SAFETY: the transmute only erases the borrow's lifetime
        // (fat-pointer layout is unchanged); the pool holds the job
        // strictly inside this call, i.e. within the borrow of `f`.
        let task = TaskPtr(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(f)
        });
        let job = {
            let mut st = lock(&self.shared.state);
            while st.job.is_some() {
                st = self.shared.done_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            st.epoch = st.epoch.wrapping_add(1);
            let job = Job { task, units: units as u32, epoch: st.epoch };
            // Reset the latch, then publish the claim cursor, then
            // the job — workers validate claims against the epoch so
            // a stale descriptor can never touch this job's units.
            self.shared.poisoned.store(false, Ordering::SeqCst);
            self.shared.done.store(0, Ordering::SeqCst);
            self.shared.claim.store(pack(job.epoch, 0), Ordering::SeqCst);
            st.job = Some(job);
            job
        };
        self.shared.injects.fetch_add(1, Ordering::Relaxed);
        self.shared.work_cv.notify_all();
        // The injector is an executor too: it merges into the unit
        // stream alongside the workers (its claims are not steals).
        execute_units(&self.shared, job, false);
        let poisoned = {
            let mut st = lock(&self.shared.state);
            while self.shared.done.load(Ordering::SeqCst) < u64::from(job.units) {
                st = self.shared.done_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            // Read the poison flag before releasing the job slot: a
            // queued injector resets it the moment it installs the
            // next job.
            let poisoned = self.shared.poisoned.load(Ordering::SeqCst);
            st.job = None;
            poisoned
        };
        // Free the job slot for the next queued injector.
        self.shared.done_cv.notify_all();
        if poisoned {
            panic!("kernel pool: a panel task panicked (job drained, pool still live)");
        }
    }
}

impl Drop for KernelPool {
    fn drop(&mut self) {
        lock(&self.shared.state).shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(job) = st.job {
                    let packed = shared.claim.load(Ordering::SeqCst);
                    if epoch_of(packed) == job.epoch && unit_of(packed) < job.units {
                        break job;
                    }
                }
                st = shared.work_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        execute_units(&shared, job, true);
    }
}

/// Claim-and-run loop shared by workers and the injector: CAS the
/// claim cursor forward while it still carries `job`'s epoch, run
/// each claimed unit, and trip the completion latch on the last one.
fn execute_units(shared: &Shared, job: Job, stealing: bool) {
    loop {
        let mut packed = shared.claim.load(Ordering::SeqCst);
        let unit = loop {
            if epoch_of(packed) != job.epoch || unit_of(packed) >= job.units {
                return;
            }
            match shared.claim.compare_exchange_weak(
                packed,
                pack(job.epoch, unit_of(packed) + 1),
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break unit_of(packed),
                Err(now) => packed = now,
            }
        };
        // SAFETY: the claim succeeded under `job.epoch`, so the
        // injector of that epoch is still blocked in `run` (its latch
        // cannot trip before this unit completes) and the closure is
        // alive.
        let f = unsafe { &*job.task.0 };
        if panic::catch_unwind(AssertUnwindSafe(|| f(unit as usize))).is_err() {
            shared.poisoned.store(true, Ordering::SeqCst);
        }
        if stealing {
            shared.steals.fetch_add(1, Ordering::Relaxed);
        }
        if shared.done.fetch_add(1, Ordering::SeqCst) + 1 == u64::from(job.units) {
            // Lock-then-notify so an injector between its latch check
            // and its wait cannot miss the wakeup.
            drop(lock(&shared.state));
            shared.done_cv.notify_all();
        }
    }
}

static GLOBAL: OnceLock<KernelPool> = OnceLock::new();

/// The process-wide pool every parallel kernel dispatches through.
/// Lazily initialized on first use with `default_threads() - 1`
/// workers (the injector is the final executor); never torn down.
pub fn global() -> &'static KernelPool {
    GLOBAL.get_or_init(|| KernelPool::with_workers(default_threads().saturating_sub(1)))
}

/// [`global`]'s counters without forcing initialization: all-zero
/// until the first parallel dispatch spawns the pool.
pub fn counters() -> PoolCounters {
    GLOBAL.get().map(KernelPool::counters).unwrap_or_default()
}

/// Measured per-dispatch overhead of the two dispatch mechanisms, in
/// nanoseconds (median over `reps`): `scoped_ns` spawns and joins
/// `tasks` no-op OS threads per call the way the retired scoped path
/// did, `inject_ns` injects a `tasks`-unit no-op job into the warm
/// [`global`] pool. This is the microbench behind the re-derived
/// engagement floors (EXPERIMENTS.md §Spawn overhead): the floor is
/// proportional to dispatch overhead, and injection undercuts scoped
/// spawn by an order of magnitude or more on every host measured.
#[derive(Clone, Copy, Debug)]
pub struct DispatchOverhead {
    pub scoped_ns: f64,
    pub inject_ns: f64,
}

/// Run the spawn-vs-inject microbench (see [`DispatchOverhead`]).
/// Warm-up dispatches run first so pool spawns and lazy buffers are
/// excluded from the measurement.
pub fn measure_dispatch_overhead(tasks: usize, reps: usize) -> DispatchOverhead {
    let tasks = tasks.max(1);
    let reps = reps.max(3);
    let pool = global();
    let noop = |_u: usize| {};
    for _ in 0..3 {
        pool.run(tasks, &noop);
        std::thread::scope(|s| {
            for _ in 0..tasks {
                s.spawn(|| {});
            }
        });
    }
    let mut scoped = Vec::with_capacity(reps);
    let mut inject = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..tasks {
                s.spawn(|| {});
            }
        });
        scoped.push(t0.elapsed().as_nanos() as f64);
        let t0 = Instant::now();
        pool.run(tasks, &noop);
        inject.push(t0.elapsed().as_nanos() as f64);
    }
    DispatchOverhead { scoped_ns: median(&mut scoped), inject_ns: median(&mut inject) }
}

fn median(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).expect("finite timings"));
    samples[samples.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn all_units_run_exactly_once() {
        let pool = KernelPool::with_workers(3);
        for units in [1usize, 2, 7, 64, 501] {
            let hits: Vec<AtomicUsize> = (0..units).map(|_| AtomicUsize::new(0)).collect();
            pool.run(units, &|u| {
                hits[u].fetch_add(1, Ordering::SeqCst);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "every unit exactly once at {units} units"
            );
        }
    }

    #[test]
    fn steady_state_spawns_stay_flat_and_injects_count() {
        let pool = KernelPool::with_workers(2);
        let before = pool.counters();
        assert_eq!(before.spawns, 2, "spawns are paid at construction");
        for _ in 0..50 {
            pool.run(8, &|_| {});
        }
        let after = pool.counters();
        assert_eq!(after.spawns, before.spawns, "no steady-state thread spawns");
        assert_eq!(after.injects, before.injects + 50);
    }

    #[test]
    fn zero_workers_degenerates_to_injector_only() {
        let pool = KernelPool::with_workers(0);
        let sum = AtomicUsize::new(0);
        pool.run(32, &|u| {
            sum.fetch_add(u + 1, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 32 * 33 / 2);
        assert_eq!(pool.counters().steals, 0, "no workers, nothing stolen");
    }

    #[test]
    fn sequential_jobs_do_not_leak_units_across_epochs() {
        // Back-to-back jobs with different unit counts: stale
        // descriptors must never claim into the next epoch (the
        // epoch-tagged CAS pins this).
        let pool = KernelPool::with_workers(3);
        for round in 0..200u32 {
            let units = 1 + (round as usize % 9);
            let count = AtomicUsize::new(0);
            pool.run(units, &|_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(count.load(Ordering::SeqCst), units, "round {round}");
        }
    }

    #[test]
    fn concurrent_injectors_serialize_and_both_complete() {
        let pool = Arc::new(KernelPool::with_workers(2));
        let total = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                s.spawn(move || {
                    for _ in 0..25 {
                        pool.run(16, &|_| {
                            total.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 25 * 16);
    }

    #[test]
    fn a_panicking_unit_poisons_the_job_but_not_the_pool() {
        let pool = KernelPool::with_workers(2);
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|u| {
                if u == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "injector re-raises the unit panic");
        // The pool must still serve jobs afterwards.
        let count = AtomicUsize::new(0);
        pool.run(8, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn dispatch_microbench_reports_positive_medians() {
        let o = measure_dispatch_overhead(2, 5);
        assert!(o.scoped_ns > 0.0 && o.inject_ns > 0.0);
    }
}
