//! Storage element types for the native compute layer.
//!
//! The kernels are generic over the *storage* element ([`Element`]):
//! operands and outputs live in the job's declared precision while
//! every partial sum accumulates in f32 — the IPU AMP contract the
//! paper benchmarks (FP16 inputs, FP32 partials), and the reason the
//! FP16 kernels are a memory-bandwidth story rather than a different
//! arithmetic one. Two implementations exist:
//!
//! * `f32` — identity conversions; the compiler erases them, so the
//!   monomorphized f32 kernels are byte-for-byte the pre-generic ones.
//! * [`F16`] — IEEE 754 binary16 stored as its raw bit pattern, with
//!   in-repo software conversion (round-to-nearest-even on the way in,
//!   exact widening on the way out; no external dependency). The
//!   offline toolchain has no `half` crate, and the conversion is ~20
//!   lines each way.
//!
//! Conversion contract (pinned exhaustively in the tests below):
//! f16 -> f32 -> f16 is bit-identical for **all** 65536 bit patterns
//! (signs, subnormals, infinities and every NaN payload included —
//! modulo the quiet bit on signaling NaNs, which Rust permits
//! platforms to set when an f32 moves through registers), and
//! f32 -> f16 rounds to nearest-even with overflow to infinity and
//! underflow through the subnormal range to signed zero.

use crate::DType;

/// A kernel storage element: convertible to/from the f32 the
/// accumulators run in, tagged with the [`DType`] it serves.
///
/// Implemented by `f32` (identity conversions) and [`F16`] (software
/// IEEE binary16). The SIMD tiers ([`crate::kernels::simd`]) only
/// engage for these two concrete types — checked by `TypeId`, so a
/// third-party implementation always takes the scalar path.
///
/// # Examples
///
/// ```
/// use popsparse::kernels::{Element, F16};
/// use popsparse::DType;
///
/// fn roundtrip<E: Element>(v: f32) -> f32 {
///     E::from_f32(v).to_f32()
/// }
/// assert_eq!(roundtrip::<f32>(1.0 + 1e-4), 1.0 + 1e-4);
/// assert_eq!(roundtrip::<F16>(1.0 + 1e-4), 1.0); // rounded to nearest f16
/// assert_eq!(F16::DTYPE, DType::Fp16);
/// ```
pub trait Element:
    Copy + Clone + Default + PartialEq + Send + Sync + std::fmt::Debug + 'static
{
    /// The job-level dtype this storage element implements.
    const DTYPE: DType;

    /// Additive identity (what empty output rows are filled with).
    const ZERO: Self;

    /// Quantize an f32 into this storage type (round-to-nearest-even
    /// for [`F16`], identity for `f32`).
    fn from_f32(v: f32) -> Self;

    /// Widen to f32 (exact for every representable value).
    fn to_f32(self) -> f32;
}

impl Element for f32 {
    const DTYPE: DType = DType::Fp32;
    const ZERO: Self = 0.0;

    #[inline(always)]
    fn from_f32(v: f32) -> Self {
        v
    }

    #[inline(always)]
    fn to_f32(self) -> f32 {
        self
    }
}

/// IEEE 754 binary16, stored as its raw bit pattern (1 sign, 5
/// exponent, 10 mantissa bits). Arithmetic never happens *in* f16 —
/// kernels widen to f32, accumulate, and quantize once on store — so
/// the type only needs the two conversions plus equality on bits.
///
/// `repr(transparent)` over the `u16` payload: `[F16]` slices may be
/// reinterpreted as raw 16-bit lanes, which the `f16c` SIMD tier's
/// vector loads/stores rely on. The hardware conversions there are
/// value-identical to [`F16::from_f32`]/[`F16::to_f32`] (both sides
/// are IEEE round-to-nearest-even with exact widening), so which path
/// ran is unobservable in the output bits.
///
/// # Examples
///
/// ```
/// use popsparse::kernels::F16;
///
/// assert_eq!(F16::from_f32(1.0), F16(0x3C00));
/// assert_eq!(F16(0x3C00).to_f32(), 1.0);
/// // Round-to-nearest-even: the midpoint below 1.0 + 2^-10 ties down.
/// assert_eq!(F16::from_f32(1.0 + f32::powi(2.0, -11)), F16(0x3C00));
/// // Overflow saturates to infinity.
/// assert_eq!(F16::from_f32(1e9), F16::INFINITY);
/// ```
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
#[repr(transparent)]
pub struct F16(pub u16);

impl F16 {
    /// Positive zero.
    pub const ZERO: F16 = F16(0);
    /// Largest finite value, 65504.0.
    pub const MAX: F16 = F16(0x7BFF);
    /// Smallest positive subnormal, 2^-24.
    pub const MIN_POSITIVE_SUBNORMAL: F16 = F16(0x0001);
    /// Positive infinity.
    pub const INFINITY: F16 = F16(0x7C00);

    /// Round an f32 to the nearest representable f16 (ties to even).
    /// Overflow saturates to the matching infinity; magnitudes below
    /// half the smallest subnormal flush to signed zero; NaN stays NaN.
    pub fn from_f32(v: f32) -> F16 {
        let bits = v.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let man = bits & 0x007F_FFFF;
        if exp == 0xFF {
            // Infinity or NaN. Keep the top 10 payload bits so
            // f16-originated NaNs round-trip bit-exactly; a NaN whose
            // payload lives entirely in the truncated low bits still
            // needs *some* payload to stay a NaN.
            if man == 0 {
                return F16(sign | 0x7C00);
            }
            let payload = (man >> 13) as u16 & 0x03FF;
            return F16(sign | 0x7C00 | if payload == 0 { 0x0200 } else { payload });
        }
        // Re-bias: f32 exponent bias 127, f16 bias 15.
        let e = exp - 127 + 15;
        if e >= 0x1F {
            return F16(sign | 0x7C00); // overflow -> infinity
        }
        if e <= 0 {
            // Subnormal range: the value is (man|implicit1) * 2^(e-24)
            // in units of the f16 subnormal step 2^-24. Below half the
            // smallest step the round is always to zero.
            if e < -10 {
                return F16(sign);
            }
            let m32 = man | 0x0080_0000;
            let shift = (14 - e) as u32; // 14..=24
            let man16 = (m32 >> shift) as u16;
            let rem = m32 & ((1u32 << shift) - 1);
            let half = 1u32 << (shift - 1);
            let mut h = sign | man16;
            if rem > half || (rem == half && (man16 & 1) == 1) {
                h += 1; // may carry into the smallest normal: correct
            }
            return F16(h);
        }
        // Normal: drop 13 mantissa bits with round-to-nearest-even. A
        // mantissa carry bumps the exponent (and saturates to infinity
        // at the top) through plain integer addition.
        let man16 = (man >> 13) as u16;
        let rem = man & 0x1FFF;
        let mut h = sign | ((e as u16) << 10) | man16;
        if rem > 0x1000 || (rem == 0x1000 && (man16 & 1) == 1) {
            h += 1;
        }
        F16(h)
    }

    /// Widen to f32. Exact for every bit pattern: normals and
    /// infinities re-bias, NaN payloads shift into the high mantissa
    /// bits, and subnormals are rebuilt as `mantissa * 2^-24` (exact —
    /// the product is a normal f32).
    pub fn to_f32(self) -> f32 {
        let h = self.0 as u32;
        let sign = (h & 0x8000) << 16;
        let exp = (h >> 10) & 0x1F;
        let man = h & 0x03FF;
        if exp == 0x1F {
            return f32::from_bits(sign | 0x7F80_0000 | (man << 13));
        }
        if exp == 0 {
            // Signed zero or subnormal.
            let mag = man as f32 * (1.0 / 16_777_216.0); // * 2^-24, exact
            return if sign != 0 { -mag } else { mag };
        }
        f32::from_bits(sign | ((exp + 127 - 15) << 23) | (man << 13))
    }
}

impl Element for F16 {
    const DTYPE: DType = DType::Fp16;
    const ZERO: Self = F16::ZERO;

    #[inline(always)]
    fn from_f32(v: f32) -> Self {
        F16::from_f32(v)
    }

    #[inline(always)]
    fn to_f32(self) -> f32 {
        F16::to_f32(self)
    }
}

/// Quantize an f32 slice into the storage type (used by the f16
/// differential suite and the wall bench to build operands whose f32
/// oracle sees exactly what the f16 kernel sees).
pub fn quantize<E: Element>(src: &[f32]) -> Vec<E> {
    src.iter().map(|&v| E::from_f32(v)).collect()
}

/// Widen a storage slice back to f32 (oracle comparisons).
pub fn dequantize<E: Element>(src: &[E]) -> Vec<f32> {
    src.iter().map(|&v| v.to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_f16_bit_pattern_round_trips_exactly() {
        // The representable-value property, exhaustively: widening to
        // f32 and re-quantizing reproduces every one of the 65536 bit
        // patterns — all normals, subnormals, signed zeros, infinities
        // and every NaN payload. One documented allowance: Rust
        // reserves the right (x87-class targets) to set a NaN's quiet
        // bit when an f32 moves through registers, so a signaling-NaN
        // pattern may come back with 0x0200 OR'd in — that exact
        // transformation and nothing else.
        for bits in 0..=u16::MAX {
            let h = F16(bits);
            let back = F16::from_f32(h.to_f32()).0;
            let is_nan = (bits & 0x7FFF) > 0x7C00;
            assert!(
                back == bits || (is_nan && back == (bits | 0x0200)),
                "bit pattern {bits:#06x} failed the round trip: got {back:#06x}"
            );
        }
    }

    #[test]
    fn known_values_convert_exactly() {
        for &(f, bits) in &[
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3C00),
            (-2.0, 0xC000),
            (0.5, 0x3800),
            (65504.0, 0x7BFF),                  // f16::MAX
            (f32::powi(2.0, -14), 0x0400),      // smallest normal
            (f32::powi(2.0, -24), 0x0001),      // smallest subnormal
            (f32::INFINITY, 0x7C00),
            (f32::NEG_INFINITY, 0xFC00),
        ] {
            assert_eq!(F16::from_f32(f).0, bits, "from_f32({f})");
            assert_eq!(F16(bits).to_f32(), f, "to_f32({bits:#06x})");
        }
        assert!(F16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1.0 + 2^-11 sits exactly between 1.0 and the next f16
        // (1.0 + 2^-10): ties go to the even mantissa, 1.0.
        assert_eq!(F16::from_f32(1.0 + f32::powi(2.0, -11)).0, 0x3C00);
        // The next midpoint up (odd low bit) rounds away.
        let above = (1.0 + f32::powi(2.0, -10)) + f32::powi(2.0, -11);
        assert_eq!(F16::from_f32(above).0, 0x3C02);
        // Just past a midpoint always rounds up.
        assert_eq!(F16::from_f32(1.0 + f32::powi(2.0, -11) + 1e-5).0, 0x3C01);
    }

    #[test]
    fn overflow_and_underflow_saturate() {
        // 65520 is the midpoint between MAX (65504) and 2^16; RNE
        // picks the even neighbour, which is infinity.
        assert_eq!(F16::from_f32(65520.0).0, 0x7C00);
        assert_eq!(F16::from_f32(65519.0).0, 0x7BFF);
        assert_eq!(F16::from_f32(-1e9).0, 0xFC00);
        // Half the smallest subnormal ties to (even) zero; anything
        // smaller flushes.
        assert_eq!(F16::from_f32(f32::powi(2.0, -25)).0, 0x0000);
        assert_eq!(F16::from_f32(-f32::powi(2.0, -26)).0, 0x8000);
        // 1.5 * 2^-25 rounds up to the smallest subnormal.
        assert_eq!(F16::from_f32(1.5 * f32::powi(2.0, -25)).0, 0x0001);
    }

    #[test]
    fn subnormal_rounding_carries_into_normals() {
        // The largest subnormal plus one step's midpoint rounds up
        // into the smallest normal through the plain bit increment.
        let largest_sub = F16(0x03FF).to_f32();
        let step = F16::MIN_POSITIVE_SUBNORMAL.to_f32();
        assert_eq!(F16::from_f32(largest_sub + 0.6 * step).0, 0x0400);
    }

    #[test]
    fn quantize_dequantize_are_inverse_on_representables() {
        let reps: Vec<f32> = (0..1000u16).map(|b| F16(b * 64).to_f32()).collect();
        let q: Vec<F16> = quantize(&reps);
        assert_eq!(dequantize(&q), reps);
        // f32 instantiations are the identity.
        let xs = [1.0f32, -2.5, 3.25e-9];
        let q32: Vec<f32> = quantize(&xs);
        assert_eq!(q32, xs);
    }
}
