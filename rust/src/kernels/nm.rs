//! Structured N:M sparse format and its SpMM microkernel family
//! (DESIGN.md §5.2).
//!
//! Block sparsity (the paper's axis) buys wall-clock wins by making
//! the nonzero *structure* cheap to exploit; N:M structure is the
//! other hardware-friendly family — every group of `M` consecutive
//! columns in a row holds exactly `N` nonzeros (NVIDIA's 2:4 is the
//! reference point, PAPERS.md: dense-beating at only 50% sparsity).
//! [`PreparedNm`] is the packed layout the kernels consume:
//!
//! * **values** — the `N` kept weights per `(row, group)`, row-major
//!   by `(row, group, slot)`, quantized once into the storage element
//!   (identity for f32); `m * (k / M) * N` entries, no zero padding.
//! * **idx** — the intra-group column of each kept weight as a 4-bit
//!   nibble (so `M <= 16`), two slots per byte, low nibble first:
//!   `ceil(N / 2)` bytes per group. 2:4 costs 1 byte/group, 4:8 costs
//!   2 — the metadata is ~6% of the f16 value bytes, versus the u32
//!   coordinates BSR pays per block.
//!
//! The kernel family mirrors [`crate::kernels::spmm`]'s structure:
//! a dense-like `ikj` loop over `(row, group)` with the group's
//! `M`-wide operand sliver gathered (widened) once and indexed by
//! nibble, [`N_TILE`] f32 register accumulator panels, f32
//! accumulation for both dtypes, and the `n % N_TILE` remainder routed
//! through the shared scalar tile body [`nm_tile`]. `(2, 4)` and
//! `(4, 8)` are monomorphized via const generics; other shapes take a
//! structurally identical runtime-generic path. Accumulation order is
//! `(group ascending, slot ascending)` per output element on **every**
//! path — scalar monomorphized, scalar generic, and the AVX2/F16C
//! tier in [`crate::kernels::simd`] — so all paths are bit-identical
//! and the scalar loops stay numerics-defining (PR 8's three-rule
//! contract: lanes span only the batch axis, separate mul + add, no
//! FMA, value-exact f16 conversions).
//!
//! Parallelism reuses the nnz-balanced row-panel machinery of
//! [`crate::kernels::parallel`] (N:M rows are structurally uniform,
//! so the balanced partition degenerates to an equal-row split, but
//! the mechanism — contiguous panels over disjoint `split_at_mut`
//! output slices, parallel == serial bit-exact — is shared).

use crate::error::{Error, Result};
use crate::kernels::element::Element;
use crate::kernels::parallel::{parallel_engages, partition_rows_balanced, with_merge_units};
use crate::kernels::pool::{self, SendPtr};
use crate::kernels::spmm::N_TILE;
use crate::util::Rng;

/// A structured N:M sparse matrix in packed kernel-ready layout,
/// stored in element type `E`.
///
/// Invariants (established by every constructor): `1 <= nm_n <= nm_m
/// <= 16`, `k % nm_m == 0`, `values.len() == m * (k / nm_m) * nm_n`,
/// `idx.len() == m * (k / nm_m) * ceil(nm_n / 2)`, and every nibble is
/// `< nm_m`. Within a group, slots are stored in ascending intra-group
/// column order.
///
/// # Examples
///
/// ```
/// use popsparse::kernels::{spmm_nm, PreparedNm};
///
/// // One row, k = 4, 2:4 — keep columns 1 and 3 with weights 2 and 3.
/// let p: PreparedNm = PreparedNm::new(1, 4, 2, 4, vec![2.0, 3.0], vec![0x31]).unwrap();
/// let x = vec![1.0f32, 10.0, 100.0, 1000.0];
/// let mut y = vec![f32::NAN; 1];
/// spmm_nm(&p, &x, 1, &mut y).unwrap();
/// assert_eq!(y[0], 2.0 * 10.0 + 3.0 * 1000.0);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct PreparedNm<E: Element = f32> {
    /// Element-level rows.
    pub m: usize,
    /// Element-level cols.
    pub k: usize,
    /// N of N:M — kept weights per group.
    pub nm_n: usize,
    /// M of N:M — group width along `k`.
    pub nm_m: usize,
    /// Kept weights, row-major by `(row, group, slot)` (quantized once
    /// at conversion for narrow `E`).
    pub values: Vec<E>,
    /// Intra-group column nibbles, two slots per byte (low nibble =
    /// even slot), `ceil(nm_n / 2)` bytes per group.
    pub idx: Vec<u8>,
}

/// Validate an `(nm_n, nm_m)` structure against a `k` extent.
fn check_structure(k: usize, nm_n: usize, nm_m: usize) -> Result<()> {
    if nm_n == 0 || nm_n > nm_m || nm_m > 16 || nm_m < 2 {
        return Err(Error::InvalidFormat(format!(
            "unsupported N:M structure {nm_n}:{nm_m} (need 1 <= N <= M <= 16, M >= 2)"
        )));
    }
    if k % nm_m != 0 {
        return Err(Error::InvalidFormat(format!(
            "k = {k} is not a multiple of the N:M group width {nm_m}"
        )));
    }
    Ok(())
}

impl<E: Element> PreparedNm<E> {
    /// Build from pre-packed buffers, validating every invariant
    /// (lengths and nibble ranges).
    pub fn new(
        m: usize,
        k: usize,
        nm_n: usize,
        nm_m: usize,
        values: Vec<E>,
        idx: Vec<u8>,
    ) -> Result<Self> {
        check_structure(k, nm_n, nm_m)?;
        let groups = k / nm_m;
        let gb = nm_n.div_ceil(2);
        if values.len() != m * groups * nm_n {
            return Err(Error::InvalidFormat(format!(
                "N:M values has {} entries, layout needs {}",
                values.len(),
                m * groups * nm_n
            )));
        }
        if idx.len() != m * groups * gb {
            return Err(Error::InvalidFormat(format!(
                "N:M idx has {} bytes, layout needs {}",
                idx.len(),
                m * groups * gb
            )));
        }
        let p = Self { m, k, nm_n, nm_m, values, idx };
        for r in 0..m {
            for g in 0..groups {
                for s in 0..nm_n {
                    let ci = p.idx_of(r, g, s);
                    if ci >= nm_m {
                        return Err(Error::InvalidFormat(format!(
                            "N:M nibble {ci} at (row {r}, group {g}, slot {s}) \
                             exceeds group width {nm_m}"
                        )));
                    }
                }
            }
        }
        Ok(p)
    }

    /// Pack a row-major `m x k` dense matrix: per group, keep the
    /// `nm_n` largest-magnitude entries (ties keep the lower column),
    /// stored in ascending intra-group column order. A matrix that
    /// already satisfies the N:M structure round-trips exactly through
    /// [`PreparedNm::to_dense`] (modulo the one-time quantization into
    /// `E`).
    pub fn from_dense(m: usize, k: usize, nm_n: usize, nm_m: usize, a: &[f32]) -> Result<Self> {
        check_structure(k, nm_n, nm_m)?;
        if a.len() != m * k {
            return Err(Error::InvalidFormat(format!(
                "dense input has {} elements, needs {m} x {k}",
                a.len()
            )));
        }
        let groups = k / nm_m;
        let gb = nm_n.div_ceil(2);
        let mut values = Vec::with_capacity(m * groups * nm_n);
        let mut idx = vec![0u8; m * groups * gb];
        for r in 0..m {
            for g in 0..groups {
                let sliver = &a[r * k + g * nm_m..r * k + (g + 1) * nm_m];
                // Kept set: nm_n largest magnitudes, lower column wins
                // ties. nm_m <= 16, so a selection scan is fine.
                let mut order: Vec<usize> = (0..nm_m).collect();
                order.sort_by(|&i, &j| {
                    sliver[j]
                        .abs()
                        .partial_cmp(&sliver[i].abs())
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(i.cmp(&j))
                });
                let mut kept: Vec<usize> = order[..nm_n].to_vec();
                kept.sort_unstable();
                let ibase = (r * groups + g) * gb;
                for (s, &ci) in kept.iter().enumerate() {
                    values.push(E::from_f32(sliver[ci]));
                    idx[ibase + s / 2] |= (ci as u8) << (4 * (s % 2));
                }
            }
        }
        Ok(Self { m, k, nm_n, nm_m, values, idx })
    }

    /// Realize a deterministic N:M operand from a seed: per group,
    /// `nm_n` distinct intra-group columns chosen uniformly and
    /// normally-distributed weights, both from one seeded stream (the
    /// prepared-cache miss path for [`Mode::Nm`] jobs; the f32 value
    /// stream is dtype-independent, so the F16 operand is exactly the
    /// quantized view of the F32 one).
    ///
    /// [`Mode::Nm`]: crate::coordinator::request::Mode::Nm
    pub fn from_pattern(m: usize, k: usize, nm_n: usize, nm_m: usize, seed: u64) -> Result<Self> {
        check_structure(k, nm_n, nm_m)?;
        let groups = k / nm_m;
        let gb = nm_n.div_ceil(2);
        let mut rng = Rng::seed_from_u64(seed ^ 0x4E4D_5350); // "NMSP"
        let mut values = Vec::with_capacity(m * groups * nm_n);
        let mut idx = vec![0u8; m * groups * gb];
        let mut cols = [0usize; 16];
        for r in 0..m {
            for g in 0..groups {
                for (c, slot) in cols[..nm_m].iter_mut().enumerate() {
                    *slot = c;
                }
                // Partial Fisher-Yates: the first nm_n entries are a
                // uniform distinct sample of 0..nm_m.
                for s in 0..nm_n {
                    let pick = s + (rng.next_u64() as usize) % (nm_m - s);
                    cols.swap(s, pick);
                }
                let mut kept = [0usize; 16];
                kept[..nm_n].copy_from_slice(&cols[..nm_n]);
                kept[..nm_n].sort_unstable();
                let ibase = (r * groups + g) * gb;
                for (s, &ci) in kept[..nm_n].iter().enumerate() {
                    values.push(E::from_f32(rng.normal() as f32));
                    idx[ibase + s / 2] |= (ci as u8) << (4 * (s % 2));
                }
            }
        }
        Ok(Self { m, k, nm_n, nm_m, values, idx })
    }

    /// Number of column groups per row.
    pub fn groups(&self) -> usize {
        self.k / self.nm_m
    }

    /// Index bytes per group (`ceil(nm_n / 2)`).
    pub fn group_bytes(&self) -> usize {
        self.nm_n.div_ceil(2)
    }

    /// Stored (structural) nonzeros: `m * groups * nm_n`.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// The intra-group column of `(row, group, slot)`.
    #[inline(always)]
    pub fn idx_of(&self, r: usize, g: usize, s: usize) -> usize {
        let byte = self.idx[(r * self.groups() + g) * self.group_bytes() + s / 2];
        (if s % 2 == 0 { byte & 0x0F } else { byte >> 4 }) as usize
    }

    /// Approximate heap footprint in bytes (cache sizing aid).
    pub fn bytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<E>() + self.idx.len()
    }

    /// Unpack to a row-major `m x k` dense matrix, widening values to
    /// f32 (oracle comparisons; zeros everywhere the structure dropped).
    pub fn to_dense(&self) -> Vec<f32> {
        let groups = self.groups();
        let mut out = vec![0f32; self.m * self.k];
        for r in 0..self.m {
            for g in 0..groups {
                let vbase = (r * groups + g) * self.nm_n;
                for s in 0..self.nm_n {
                    let ci = self.idx_of(r, g, s);
                    out[r * self.k + g * self.nm_m + ci] = self.values[vbase + s].to_f32();
                }
            }
        }
        out
    }
}

/// Map a job density to the N:M structure that realizes it exactly,
/// preferring the narrower group: `Some((n, m))` with `m` in {4, 8},
/// `1 <= n < m` and `n / m == density`; `None` when no supported
/// structure matches (the N:M backend's feasibility gate).
///
/// # Examples
///
/// ```
/// use popsparse::kernels::nm_for_density;
///
/// assert_eq!(nm_for_density(0.5), Some((2, 4)));   // 2:4
/// assert_eq!(nm_for_density(0.25), Some((1, 4))); // 1:4
/// assert_eq!(nm_for_density(1.0 / 8.0), Some((1, 8)));
/// assert_eq!(nm_for_density(1.0 / 16.0), None);   // below 1:8
/// assert_eq!(nm_for_density(1.0), None);          // dense is dense
/// ```
pub fn nm_for_density(density: f64) -> Option<(usize, usize)> {
    for m in [4usize, 8] {
        let n = (density * m as f64).round();
        if n >= 1.0 && n < m as f64 && (n / m as f64 - density).abs() < 1e-9 {
            return Some((n as usize, m));
        }
    }
    None
}

/// Validate SpMM operand shapes against the packed matrix.
fn check_operands<E: Element>(p: &PreparedNm<E>, x: &[E], n: usize, y: &[E]) -> Result<()> {
    if x.len() != p.k * n {
        return Err(Error::InvalidFormat(format!(
            "x has {} elements, N:M kernel needs {} x {n}",
            x.len(),
            p.k
        )));
    }
    if y.len() != p.m * n {
        return Err(Error::InvalidFormat(format!(
            "y has {} elements, N:M kernel needs {} x {n}",
            y.len(),
            p.m
        )));
    }
    Ok(())
}

/// Single-threaded N:M SpMM: `y = A x` with `A` packed, `x` row-major
/// `k x n`, `y` row-major `m x n`, all in storage type `E` with f32
/// accumulation. Overwrites all of `y`. Dispatches to the widest SIMD
/// tier the machine supports; the result is bit-identical across
/// tiers.
pub fn spmm_nm<E: Element>(p: &PreparedNm<E>, x: &[E], n: usize, y: &mut [E]) -> Result<()> {
    check_operands(p, x, n, y)?;
    nm_rows(p, x, n, 0, p.m, y);
    Ok(())
}

/// [`spmm_nm`] pinned to the scalar fallback path, bypassing SIMD
/// dispatch — the numerics-defining reference the differential suite
/// pins the tiers against.
pub fn spmm_nm_scalar<E: Element>(
    p: &PreparedNm<E>,
    x: &[E],
    n: usize,
    y: &mut [E],
) -> Result<()> {
    check_operands(p, x, n, y)?;
    nm_rows_scalar(p, x, n, 0, p.m, y);
    Ok(())
}

/// Compute rows `[r0, r1)` into `y_panel` (the panel's own output
/// slice of length `(r1 - r0) * n`): SIMD offer first, scalar
/// dispatch otherwise. The unit of work a parallel panel executes.
pub(crate) fn nm_rows<E: Element>(
    p: &PreparedNm<E>,
    x: &[E],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [E],
) {
    debug_assert_eq!(y_panel.len(), (r1 - r0) * n);
    if crate::kernels::simd::try_spmm_nm_rows(p, x, n, r0, r1, y_panel) {
        return;
    }
    nm_rows_scalar(p, x, n, r0, r1, y_panel);
}

/// The scalar tier of [`nm_rows`]: structure dispatch into the
/// monomorphized microkernels (2:4, 4:8), runtime-generic fallback
/// elsewhere.
pub(crate) fn nm_rows_scalar<E: Element>(
    p: &PreparedNm<E>,
    x: &[E],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [E],
) {
    debug_assert_eq!(y_panel.len(), (r1 - r0) * n);
    match (p.nm_n, p.nm_m) {
        (2, 4) => nm_rows_c::<E, 2, 4>(p, x, n, r0, r1, y_panel),
        (4, 8) => nm_rows_c::<E, 4, 8>(p, x, n, r0, r1, y_panel),
        _ => nm_rows_generic(p, x, n, r0, r1, y_panel),
    }
}

/// The monomorphized microkernel: `NM_N`/`NM_M` are compile-time, so
/// the per-group gather buffer `[[f32; N_TILE]; NM_M]` is a fixed
/// stack array and the slot loop has a constant trip count. The
/// group's `M`-wide operand sliver is gathered (widened) once and
/// indexed by nibble across the group's slots — the dense-like `ikj`
/// structure, with the structure doing the column selection.
fn nm_rows_c<E: Element, const NM_N: usize, const NM_M: usize>(
    p: &PreparedNm<E>,
    x: &[E],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [E],
) {
    debug_assert_eq!((p.nm_n, p.nm_m), (NM_N, NM_M));
    let groups = p.k / NM_M;
    let gb = NM_N.div_ceil(2);
    for (ri, r) in (r0..r1).enumerate() {
        let out = &mut y_panel[ri * n..(ri + 1) * n];
        let mut j = 0;
        while j + N_TILE <= n {
            let mut acc = [0f32; N_TILE];
            for g in 0..groups {
                let mut xf = [[0f32; N_TILE]; NM_M];
                for (c, xrow) in xf.iter_mut().enumerate() {
                    let src = &x[(g * NM_M + c) * n + j..][..N_TILE];
                    for (d, &s) in xrow.iter_mut().zip(src) {
                        *d = s.to_f32();
                    }
                }
                let vbase = (r * groups + g) * NM_N;
                let ibase = (r * groups + g) * gb;
                for s in 0..NM_N {
                    let byte = p.idx[ibase + s / 2];
                    let ci = (if s % 2 == 0 { byte & 0x0F } else { byte >> 4 }) as usize;
                    let w = p.values[vbase + s].to_f32();
                    for (a, &xv) in acc.iter_mut().zip(&xf[ci]) {
                        *a += w * xv;
                    }
                }
            }
            for (o, &a) in out[j..j + N_TILE].iter_mut().zip(&acc) {
                *o = E::from_f32(a);
            }
            j += N_TILE;
        }
        if j < n {
            nm_tile(p, x, n, r, j, n - j, out);
        }
    }
}

/// Structurally identical fallback for structures without a
/// monomorphized kernel: every tile runs the shared tile body.
fn nm_rows_generic<E: Element>(
    p: &PreparedNm<E>,
    x: &[E],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [E],
) {
    for (ri, r) in (r0..r1).enumerate() {
        let out = &mut y_panel[ri * n..(ri + 1) * n];
        let mut j = 0;
        while j < n {
            let tile = N_TILE.min(n - j);
            nm_tile(p, x, n, r, j, tile, out);
            j += tile;
        }
    }
}

/// One `1 x tile` output tile of row `r` (`tile <= N_TILE` batch
/// columns starting at `j`), accumulated over every `(group, slot)` in
/// ascending order and stored into `out` (the row's own `n`-length
/// slice). This single body serves the generic path's full tiles *and*
/// every path's `n % N_TILE` remainder — including the SIMD tiers in
/// [`crate::kernels::simd`] — so remainder handling is identical to
/// the fallback by construction.
pub(crate) fn nm_tile<E: Element>(
    p: &PreparedNm<E>,
    x: &[E],
    n: usize,
    r: usize,
    j: usize,
    tile: usize,
    out: &mut [E],
) {
    let groups = p.groups();
    let gb = p.group_bytes();
    let mut acc = [0f32; N_TILE];
    for g in 0..groups {
        let vbase = (r * groups + g) * p.nm_n;
        let ibase = (r * groups + g) * gb;
        for s in 0..p.nm_n {
            let byte = p.idx[ibase + s / 2];
            let ci = (if s % 2 == 0 { byte & 0x0F } else { byte >> 4 }) as usize;
            let w = p.values[vbase + s].to_f32();
            let xrow = &x[(g * p.nm_m + ci) * n + j..][..tile];
            let mut xf = [0f32; N_TILE];
            for (d, &sv) in xf.iter_mut().zip(xrow) {
                *d = sv.to_f32();
            }
            for (a, &xv) in acc[..tile].iter_mut().zip(&xf[..tile]) {
                *a += w * xv;
            }
        }
    }
    for (o, &a) in out[j..j + tile].iter_mut().zip(&acc[..tile]) {
        *o = E::from_f32(a);
    }
}

/// Parallel N:M SpMM across row-merge units on the persistent kernel
/// pool (the shared partition core + unit buffer of
/// [`crate::kernels::parallel`]; N:M rows are uniform, so units are
/// equal row spans). Each unit owns a disjoint output slice and runs
/// the same per-row kernel as the single-threaded path, so the result
/// is bit-identical to [`spmm_nm`]'s — under any unit→worker
/// assignment.
pub fn spmm_nm_parallel<E: Element>(
    p: &PreparedNm<E>,
    x: &[E],
    n: usize,
    y: &mut [E],
    threads: usize,
) -> Result<()> {
    if x.len() != p.k * n || y.len() != p.m * n {
        return spmm_nm(p, x, n, y); // reuse the single-thread shape error
    }
    let per_row = p.groups() * p.nm_n;
    with_merge_units(p.m, p.nnz(), |_| per_row, threads, |units| {
        if units.len() <= 1 || threads <= 1 {
            return spmm_nm(p, x, n, y);
        }
        let base = SendPtr(y.as_mut_ptr());
        pool::global().run(units.len(), &|u| {
            let (r0, r1) = units[u];
            // SAFETY: units are disjoint contiguous spans of 0..m, so
            // each claimed unit writes a disjoint sub-slice of `y`;
            // the injector blocks until every unit completes.
            let panel =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * n), (r1 - r0) * n) };
            nm_rows(p, x, n, r0, r1, panel);
        });
        Ok(())
    })
}

/// The legacy scoped-spawn N:M dispatch, retained as the differential
/// reference for the pooled path (per-call OS thread spawns).
/// Bit-identical to both [`spmm_nm`] and [`spmm_nm_parallel`].
pub fn spmm_nm_parallel_scoped<E: Element>(
    p: &PreparedNm<E>,
    x: &[E],
    n: usize,
    y: &mut [E],
    threads: usize,
) -> Result<()> {
    let per_row = p.groups() * p.nm_n;
    let panels = partition_rows_balanced(p.m, p.nnz(), |_| per_row, threads);
    if panels.len() <= 1 {
        return spmm_nm(p, x, n, y);
    }
    if x.len() != p.k * n || y.len() != p.m * n {
        return spmm_nm(p, x, n, y); // reuse the single-thread shape error
    }
    std::thread::scope(|scope| {
        let mut rest: &mut [E] = y;
        for &(r0, r1) in &panels {
            let (panel, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * n);
            rest = tail;
            scope.spawn(move || nm_rows(p, x, n, r0, r1, panel));
        }
    });
    Ok(())
}

/// N:M SpMM with automatic parallelism: panel-parallel when the job
/// clears the dtype-scaled engagement floor
/// ([`crate::kernels::parallel::parallel_engages`]), single-threaded
/// otherwise; bit-identical either way.
pub fn spmm_nm_auto<E: Element>(
    p: &PreparedNm<E>,
    x: &[E],
    n: usize,
    y: &mut [E],
    threads: usize,
) -> Result<()> {
    let flops = 2.0 * p.nnz() as f64 * n as f64;
    if parallel_engages(E::DTYPE, flops, threads) {
        spmm_nm_parallel(p, x, n, y, threads)
    } else {
        spmm_nm(p, x, n, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::element::{dequantize, quantize, F16};
    use crate::kernels::spmm::close_enough_for;
    use crate::DType;

    /// Dense row-major oracle: y = A x in f32.
    fn dense_ref(a: &[f32], x: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0f32; m * n];
        for r in 0..m {
            for l in 0..k {
                let w = a[r * k + l];
                for j in 0..n {
                    y[r * n + j] += w * x[l * n + j];
                }
            }
        }
        y
    }

    #[test]
    fn packed_format_round_trips_through_dense() {
        for &(nm_n, nm_m) in &[(2usize, 4usize), (4, 8), (1, 4), (3, 8)] {
            let p = PreparedNm::<f32>::from_pattern(5, nm_m * 3, nm_n, nm_m, 7).unwrap();
            let dense = p.to_dense();
            let back = PreparedNm::<f32>::from_dense(5, nm_m * 3, nm_n, nm_m, &dense).unwrap();
            // An N:M-compliant dense matrix repacks to the same dense
            // view; indices may differ only where dropped weights were
            // exactly zero (from_pattern's normals never are, but a
            // group with a zero weight has interchangeable slots).
            assert_eq!(back.to_dense(), dense, "{nm_n}:{nm_m}");
            assert_eq!(back.nnz(), p.nnz());
        }
    }

    #[test]
    fn from_pattern_is_deterministic_and_structured() {
        let a = PreparedNm::<f32>::from_pattern(8, 32, 2, 4, 42).unwrap();
        let b = PreparedNm::<f32>::from_pattern(8, 32, 2, 4, 42).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, PreparedNm::<f32>::from_pattern(8, 32, 2, 4, 43).unwrap());
        // Exactly N distinct ascending columns per group.
        for r in 0..a.m {
            for g in 0..a.groups() {
                let cols: Vec<usize> = (0..a.nm_n).map(|s| a.idx_of(r, g, s)).collect();
                for w in cols.windows(2) {
                    assert!(w[0] < w[1], "row {r} group {g}: {cols:?}");
                }
                assert!(cols.iter().all(|&c| c < a.nm_m));
            }
        }
        // The F16 realization is the quantized view of the f32 one.
        let a16 = PreparedNm::<F16>::from_pattern(8, 32, 2, 4, 42).unwrap();
        assert_eq!(a16.idx, a.idx);
        for (h, f) in a16.values.iter().zip(&a.values) {
            assert_eq!(*h, F16::from_f32(*f));
        }
    }

    #[test]
    fn kernel_matches_dense_oracle_per_dtype() {
        let mut rng = Rng::seed_from_u64(0x2424);
        for &(nm_n, nm_m) in &[(2usize, 4usize), (4, 8), (3, 8)] {
            for &n in &[1usize, 16, 33] {
                let (m, k) = (7, nm_m * 5); // m deliberately odd
                let p = PreparedNm::<f32>::from_pattern(m, k, nm_n, nm_m, rng.next_u64()).unwrap();
                let x: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
                let mut y = vec![f32::NAN; m * n];
                spmm_nm(&p, &x, n, &mut y).unwrap();
                let want = dense_ref(&p.to_dense(), &x, m, k, n);
                for (i, (&u, &v)) in y.iter().zip(&want).enumerate() {
                    assert!(
                        close_enough_for(DType::Fp32, u, v),
                        "{nm_n}:{nm_m} n={n} elem {i}: {u} vs {v}"
                    );
                }
                // F16 against the f32 oracle on the quantized operands.
                let p16 = PreparedNm::<F16>::from_pattern(m, k, nm_n, nm_m, 1).unwrap();
                let x16: Vec<F16> = quantize(&x);
                let mut y16 = vec![F16(0x7E00); m * n];
                spmm_nm(&p16, &x16, n, &mut y16).unwrap();
                let want16 = dense_ref(&p16.to_dense(), &dequantize(&x16), m, k, n);
                for (i, (&u, &v)) in dequantize(&y16).iter().zip(&want16).enumerate() {
                    assert!(
                        close_enough_for(DType::Fp16, u, v),
                        "f16 {nm_n}:{nm_m} n={n} elem {i}: {u} vs {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn dispatched_kernel_is_bit_identical_to_pinned_scalar() {
        let mut rng = Rng::seed_from_u64(0x51D2);
        for &(nm_n, nm_m) in &[(2usize, 4usize), (4, 8), (3, 8)] {
            let (m, k, n) = (6, nm_m * 4, 33); // full tiles + remainder
            let p = PreparedNm::<f32>::from_pattern(m, k, nm_n, nm_m, rng.next_u64()).unwrap();
            let x: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let (mut y, mut y_ref) = (vec![f32::NAN; m * n], vec![f32::NAN; m * n]);
            spmm_nm(&p, &x, n, &mut y).unwrap();
            spmm_nm_scalar(&p, &x, n, &mut y_ref).unwrap();
            for (i, (&u, &v)) in y.iter().zip(&y_ref).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "{nm_n}:{nm_m} elem {i}");
            }
            let p16 = PreparedNm::<F16>::from_pattern(m, k, nm_n, nm_m, 2).unwrap();
            let x16: Vec<F16> = quantize(&x);
            let (mut y16, mut y16_ref) = (vec![F16(0x7E00); m * n], vec![F16(0x7E00); m * n]);
            spmm_nm(&p16, &x16, n, &mut y16).unwrap();
            spmm_nm_scalar(&p16, &x16, n, &mut y16_ref).unwrap();
            assert_eq!(y16, y16_ref, "f16 {nm_n}:{nm_m}");
        }
    }

    #[test]
    fn all_zero_groups_produce_zero_output() {
        // Structural slots with zero *values*: the degenerate case the
        // format permits (a group whose kept weights are all zero).
        let p: PreparedNm =
            PreparedNm::new(2, 8, 2, 4, vec![0.0; 2 * 2 * 2], vec![0x10; 2 * 2]).unwrap();
        let n = 5;
        let x = vec![1f32; 8 * n];
        let mut y = vec![f32::NAN; 2 * n];
        spmm_nm(&p, &x, n, &mut y).unwrap();
        assert!(y.iter().all(|&v| v == 0.0), "{y:?}");
    }

    #[test]
    fn parallel_matches_serial_bit_exactly() {
        let mut rng = Rng::seed_from_u64(0x9A12);
        for &(m, n) in &[(64usize, 21usize), (7, 16)] {
            let p = PreparedNm::<f32>::from_pattern(m, 32, 2, 4, rng.next_u64()).unwrap();
            let x: Vec<f32> = (0..32 * n).map(|_| rng.normal() as f32).collect();
            let mut y1 = vec![f32::NAN; m * n];
            let mut y4 = vec![f32::NAN; m * n];
            spmm_nm(&p, &x, n, &mut y1).unwrap();
            spmm_nm_parallel(&p, &x, n, &mut y4, 4).unwrap();
            assert_eq!(y1, y4, "m={m} n={n}");
            let p16 = PreparedNm::<F16>::from_pattern(m, 32, 2, 4, 3).unwrap();
            let x16: Vec<F16> = quantize(&x);
            let mut z1 = vec![F16(0x7E00); m * n];
            let mut z4 = vec![F16(0x7E00); m * n];
            spmm_nm(&p16, &x16, n, &mut z1).unwrap();
            spmm_nm_parallel(&p16, &x16, n, &mut z4, 4).unwrap();
            assert_eq!(z1, z4, "f16 m={m} n={n}");
        }
    }

    #[test]
    fn auto_handles_tiny_inputs_and_shape_errors() {
        let p = PreparedNm::<f32>::from_pattern(4, 8, 2, 4, 1).unwrap();
        let x = vec![0f32; 8 * 3];
        let mut y = vec![f32::NAN; 4 * 3];
        spmm_nm_auto(&p, &x, 3, &mut y, 8).unwrap();
        assert!(y.iter().all(|&v| v == 0.0));
        assert!(spmm_nm(&p, &x[..7], 3, &mut y).is_err());
        assert!(spmm_nm(&p, &x, 3, &mut y[..7]).is_err());
    }

    #[test]
    fn constructor_rejects_bad_structure() {
        assert!(PreparedNm::<f32>::from_pattern(4, 10, 2, 4, 1).is_err(), "k % M != 0");
        assert!(PreparedNm::<f32>::from_pattern(4, 8, 0, 4, 1).is_err(), "N = 0");
        assert!(PreparedNm::<f32>::from_pattern(4, 8, 5, 4, 1).is_err(), "N > M");
        assert!(PreparedNm::<f32>::from_pattern(4, 34, 2, 17, 1).is_err(), "M > 16");
        // Out-of-range nibble caught by `new`.
        assert!(PreparedNm::<f32>::new(1, 4, 2, 4, vec![1.0, 1.0], vec![0x41]).is_err());
        // Wrong buffer lengths caught by `new`.
        assert!(PreparedNm::<f32>::new(1, 4, 2, 4, vec![1.0], vec![0x10]).is_err());
        assert!(PreparedNm::<f32>::new(1, 4, 2, 4, vec![1.0, 1.0], vec![]).is_err());
    }

    #[test]
    fn density_maps_to_supported_structures() {
        assert_eq!(nm_for_density(0.5), Some((2, 4)));
        assert_eq!(nm_for_density(0.25), Some((1, 4)));
        assert_eq!(nm_for_density(0.75), Some((3, 4)));
        assert_eq!(nm_for_density(1.0 / 8.0), Some((1, 8)));
        assert_eq!(nm_for_density(3.0 / 8.0), Some((3, 8)));
        assert_eq!(nm_for_density(1.0 / 16.0), None);
        assert_eq!(nm_for_density(1.0), None);
        assert_eq!(nm_for_density(0.3), None, "not exactly representable");
    }
}
