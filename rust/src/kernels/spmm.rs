//! Tiled block-sparse SpMM microkernels over [`PreparedBsr`].
//!
//! Layout of the computation (Gale et al.'s row-offset recipe, scaled
//! to one CPU core): the output is walked one block-row at a time; for
//! each block-row the batch dimension `n` is processed in fixed-width
//! tiles of [`N_TILE`] columns so the `b x N_TILE` accumulator panel
//! lives in registers across the whole block-row — every `x` row
//! segment is loaded once per block and reused across the block's `b`
//! output rows, and each output element is **written exactly once**
//! (block-rows with no blocks are zero-filled). Block sizes 4, 8 and
//! 16 are monomorphized via const generics so the inner loops have
//! compile-time trip counts and autovectorize; other block sizes take
//! a structurally identical generic fallback.
//!
//! Numerics: the kernels are generic over the storage element
//! ([`Element`]) — operands and outputs live in the job's dtype while
//! **every partial sum accumulates in f32** (the IPU AMP contract:
//! FP16 inputs, FP32 partials). Per output element, contributions
//! accumulate in the same (block, then intra-block column) order as
//! the naive references ([`crate::runtime::spmm_ref`],
//! [`BlockCoo::spmm_dense`]), but the tiled path does not skip
//! explicit zeros inside blocks and keeps partial sums in a register
//! panel — agreement with the references is therefore contracted to
//! the documented tolerance ([`close_enough`] /
//! [`close_enough_for`]), not bit-equality (DESIGN.md §5).
//!
//! SIMD: [`spmm`] first offers each row panel to the arch-gated wide
//! kernels in [`crate::kernels::simd`] (DESIGN.md §5.1); the scalar
//! loops in this file are the mandatory fallback and the
//! numerics-defining reference. The wide paths are pinned
//! **bit-identical** to the scalar ones per dtype — same mul/add
//! (no FMA) in the same order, lanes across the independent batch
//! columns — so dispatch is invisible to the tolerance contract and
//! to the PR-6 replay/parity contracts. [`spmm_scalar`] bypasses
//! dispatch for tests and differential harnesses.
//!
//! [`BlockCoo::spmm_dense`]: crate::sparse::coo::BlockCoo::spmm_dense

use crate::error::{Error, Result};
use crate::kernels::element::Element;
use crate::kernels::prepared::PreparedBsr;
use crate::DType;

/// Batch-dimension tile width (f32 accumulator lanes) of the register
/// panel. 16 lanes = two AVX2 / one AVX-512 vector per accumulator
/// row; the `n % N_TILE` remainder takes a narrower epilogue.
pub const N_TILE: usize = 16;

/// Tolerance contract for comparing f32 kernel output against the
/// naive references: relative error per element, with an absolute
/// floor for near-zero outputs. Tiling reorders f32 partial sums (and
/// keeps them in registers), so oracle comparisons where a tiled path
/// is under test use this bound instead of bit-equality.
pub const REL_TOLERANCE: f32 = 1e-5;

/// Absolute floor companion to [`REL_TOLERANCE`].
pub const ABS_TOLERANCE: f32 = 1e-5;

/// Tolerance contract for the FP16 storage kernels, **against an f32
/// oracle evaluated on the same f16-quantized operands** (quantize
/// first, then hand both sides identical values — input rounding is
/// then shared, not part of the error budget). What remains is the
/// single output-store rounding (≤ 2^-11 ≈ 4.9e-4 relative) plus
/// f32 accumulation-order differences; 2e-3 is a 4x margin over the
/// store rounding. Comparisons against an oracle on *unquantized*
/// operands are outside the contract — input rounding error compounds
/// with the reduction length there.
pub const REL_TOLERANCE_F16: f32 = 2e-3;

/// Absolute floor companion to [`REL_TOLERANCE_F16`] (an output that
/// rounds to the nearest f16 can be off by half an f16 subnormal step
/// near zero, and cancellation leaves small absolute residue).
pub const ABS_TOLERANCE_F16: f32 = 2e-3;

/// The (relative, absolute) tolerance pair contracted for a storage
/// dtype's kernel output.
pub fn tolerance(dtype: DType) -> (f32, f32) {
    match dtype {
        DType::Fp32 => (REL_TOLERANCE, ABS_TOLERANCE),
        DType::Fp16 => (REL_TOLERANCE_F16, ABS_TOLERANCE_F16),
    }
}

/// Whether two f32 values agree within the documented kernel tolerance
/// for `dtype` storage:
/// `|a - b| <= abs + rel * max(|a|, |b|)` with `(rel, abs)` from
/// [`tolerance`]. For FP16 the contract presumes both sides consumed
/// the same f16-quantized operands (see [`REL_TOLERANCE_F16`]).
///
/// # Examples
///
/// ```
/// use popsparse::kernels::close_enough_for;
/// use popsparse::DType;
///
/// // 5e-4 relative error: inside the f16 contract, outside f32's.
/// assert!(close_enough_for(DType::Fp16, 1.0, 1.0005));
/// assert!(!close_enough_for(DType::Fp32, 1.0, 1.0005));
/// ```
pub fn close_enough_for(dtype: DType, a: f32, b: f32) -> bool {
    let (rel, abs) = tolerance(dtype);
    (a - b).abs() <= abs + rel * a.abs().max(b.abs())
}

/// [`close_enough_for`] at the f32 contract — the original PR-4
/// tolerance, unchanged.
pub fn close_enough(a: f32, b: f32) -> bool {
    close_enough_for(DType::Fp32, a, b)
}

/// Validate SpMM operand shapes against the prepared matrix.
fn check_operands<E: Element>(p: &PreparedBsr<E>, x: &[E], n: usize, y: &[E]) -> Result<()> {
    if x.len() != p.k * n {
        return Err(Error::InvalidFormat(format!(
            "x has {} elements, kernel needs {} x {n}",
            x.len(),
            p.k
        )));
    }
    if y.len() != p.m * n {
        return Err(Error::InvalidFormat(format!(
            "y has {} elements, kernel needs {} x {n}",
            y.len(),
            p.m
        )));
    }
    Ok(())
}

/// Single-threaded tiled SpMM: `y = A x` with `A` prepared, `x`
/// row-major `k x n`, `y` row-major `m x n`, all in storage type `E`
/// with f32 accumulation. Overwrites all of `y` (no pre-zeroing
/// needed). Dispatches to the widest SIMD tier the machine supports
/// ([`crate::kernels::simd`]); the result is bit-identical across
/// tiers.
pub fn spmm<E: Element>(p: &PreparedBsr<E>, x: &[E], n: usize, y: &mut [E]) -> Result<()> {
    check_operands(p, x, n, y)?;
    spmm_rows(p, x, n, 0, p.mb(), y);
    Ok(())
}

/// [`spmm`] pinned to the scalar fallback path, bypassing SIMD
/// dispatch. The output is bit-identical to [`spmm`]'s on every
/// machine — this entry point exists so tests and differential
/// harnesses can *prove* that, and as the reference when a wide tier
/// is suspected of misbehaving.
pub fn spmm_scalar<E: Element>(p: &PreparedBsr<E>, x: &[E], n: usize, y: &mut [E]) -> Result<()> {
    check_operands(p, x, n, y)?;
    spmm_rows_scalar(p, x, n, 0, p.mb(), y);
    Ok(())
}

/// Compute block-rows `[r0, r1)` into `y_panel`, the panel's own
/// output slice of length `(r1 - r0) * b * n`. Offers the panel to
/// the SIMD tiers first, then dispatches to the scalar
/// block-size-specialized microkernel. This is the unit of work a
/// parallel panel executes; `spmm` is the single-panel case.
pub(crate) fn spmm_rows<E: Element>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [E],
) {
    debug_assert_eq!(y_panel.len(), (r1 - r0) * p.b * n);
    if crate::kernels::simd::try_spmm_rows(p, x, n, r0, r1, y_panel) {
        return;
    }
    spmm_rows_scalar(p, x, n, r0, r1, y_panel);
}

/// The scalar tier of [`spmm_rows`]: block-size dispatch into the
/// monomorphized scalar microkernels, no SIMD offer.
pub(crate) fn spmm_rows_scalar<E: Element>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [E],
) {
    debug_assert_eq!(y_panel.len(), (r1 - r0) * p.b * n);
    match p.b {
        4 => spmm_rows_b::<E, 4>(p, x, n, r0, r1, y_panel),
        8 => spmm_rows_b::<E, 8>(p, x, n, r0, r1, y_panel),
        16 => spmm_rows_b::<E, 16>(p, x, n, r0, r1, y_panel),
        _ => spmm_rows_generic(p, x, n, r0, r1, y_panel),
    }
}

/// The monomorphized microkernel: `B` is a compile-time block size, so
/// the accumulator panel `[[f32; N_TILE]; B]` is a fixed-size stack
/// array and every inner loop has a constant trip count. The `x` tile
/// row is widened into an f32 stack buffer once per (block, column)
/// and reused across the block's `B` output rows, so narrow storage
/// pays one conversion per load, not one per multiply (for `E = f32`
/// the widening is the identity and the buffer is a register copy).
fn spmm_rows_b<E: Element, const B: usize>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [E],
) {
    debug_assert_eq!(p.b, B);
    for (ri, r) in (r0..r1).enumerate() {
        let (lo, hi) = (p.row_ptr[r] as usize, p.row_ptr[r + 1] as usize);
        let out = &mut y_panel[ri * B * n..(ri + 1) * B * n];
        if lo == hi {
            out.fill(E::ZERO);
            continue;
        }
        let mut j = 0;
        while j < n {
            let tile = N_TILE.min(n - j);
            spmm_tile_b::<E, B>(p, x, n, lo, hi, j, tile, out);
            j += tile;
        }
    }
}

/// One `B x tile` output tile of a block-row (`tile <= N_TILE`
/// columns starting at batch column `j`), accumulated from blocks
/// `[lo, hi)` and stored into `out` (the block-row's own `B x n`
/// slice). This single body serves the full tiles *and* the `n %
/// N_TILE` remainder of the scalar path, and is the remainder path of
/// every SIMD tier ([`crate::kernels::simd`]) — sharing it is what
/// makes the tiers' remainder handling identical to the fallback by
/// construction.
#[allow(clippy::too_many_arguments)]
pub(crate) fn spmm_tile_b<E: Element, const B: usize>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    lo: usize,
    hi: usize,
    j: usize,
    tile: usize,
    out: &mut [E],
) {
    let bsz = B * B;
    let mut acc = [[0f32; N_TILE]; B];
    for blk in lo..hi {
        let c = p.cols[blk] as usize;
        let vals = &p.values[blk * bsz..(blk + 1) * bsz];
        for bc in 0..B {
            let xrow = &x[(c * B + bc) * n + j..][..tile];
            let mut xf = [0f32; N_TILE];
            for (d, &s) in xf.iter_mut().zip(xrow) {
                *d = s.to_f32();
            }
            for (br, acc_row) in acc.iter_mut().enumerate() {
                let w = vals[br * B + bc].to_f32();
                for (a, &xv) in acc_row.iter_mut().zip(&xf[..tile]) {
                    *a += w * xv;
                }
            }
        }
    }
    for (br, acc_row) in acc.iter().enumerate() {
        for (o, &a) in out[br * n + j..br * n + j + tile].iter_mut().zip(&acc_row[..tile]) {
            *o = E::from_f32(a);
        }
    }
}

/// Largest block size whose generic-path accumulator panel fits the
/// stack buffer below (covers every monomorphized size and the odd
/// sizes between; the hot numeric path never allocates for `b` ≤ 16).
const GENERIC_STACK_B: usize = 16;

/// Structurally identical fallback for block sizes without a
/// monomorphized kernel (`b = 1` unstructured patterns, odd sizes).
/// The accumulator panel lives on the stack for `b` ≤
/// [`GENERIC_STACK_B`] — the whole practical range, keeping pooled
/// steady-state dispatch allocation-free (`tests/hot_path_alloc.rs`)
/// — with a heap fallback for larger exotic blocks.
fn spmm_rows_generic<E: Element>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [E],
) {
    let b = p.b;
    let bsz = b * b;
    let mut stack_acc = [0f32; GENERIC_STACK_B * N_TILE];
    let mut heap_acc;
    let acc: &mut [f32] = if b <= GENERIC_STACK_B {
        &mut stack_acc[..b * N_TILE]
    } else {
        heap_acc = vec![0f32; b * N_TILE];
        &mut heap_acc
    };
    for (ri, r) in (r0..r1).enumerate() {
        let (lo, hi) = (p.row_ptr[r] as usize, p.row_ptr[r + 1] as usize);
        let out = &mut y_panel[ri * b * n..(ri + 1) * b * n];
        if lo == hi {
            out.fill(E::ZERO);
            continue;
        }
        let mut j = 0;
        while j < n {
            let tile = N_TILE.min(n - j);
            acc.fill(0.0);
            for blk in lo..hi {
                let c = p.cols[blk] as usize;
                let vals = &p.values[blk * bsz..(blk + 1) * bsz];
                for bc in 0..b {
                    let xrow = &x[(c * b + bc) * n + j..][..tile];
                    let mut xf = [0f32; N_TILE];
                    for (d, &s) in xf.iter_mut().zip(xrow) {
                        *d = s.to_f32();
                    }
                    for br in 0..b {
                        let w = vals[br * b + bc].to_f32();
                        let acc_row = &mut acc[br * N_TILE..br * N_TILE + tile];
                        for (a, &xv) in acc_row.iter_mut().zip(&xf[..tile]) {
                            *a += w * xv;
                        }
                    }
                }
            }
            for br in 0..b {
                for (o, &a) in out[br * n + j..br * n + j + tile]
                    .iter_mut()
                    .zip(&acc[br * N_TILE..br * N_TILE + tile])
                {
                    *o = E::from_f32(a);
                }
            }
            j += tile;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::element::{dequantize, quantize, F16};
    use crate::sparse::patterns;
    use crate::util::Rng;

    fn reference(p: &PreparedBsr, x: &[f32], n: usize) -> Vec<f32> {
        p.to_block_coo().unwrap().spmm_dense(x, n).unwrap()
    }

    fn assert_close(a: &[f32], b: &[f32], context: &str) {
        assert_eq!(a.len(), b.len(), "{context}: length");
        for (i, (&u, &v)) in a.iter().zip(b).enumerate() {
            assert!(close_enough(u, v), "{context}: element {i}: {u} vs {v}");
        }
    }

    #[test]
    fn specialized_kernels_match_reference() {
        let mut rng = Rng::seed_from_u64(0xBEEF);
        for &b in &[4usize, 8, 16] {
            for &n in &[1usize, 16, 33] {
                let mb = 6;
                let mask =
                    patterns::uniform(mb * b, mb * b, b, mb * mb / 3, rng.next_u64()).unwrap();
                let coo = patterns::with_values(&mask, rng.next_u64());
                let p = PreparedBsr::from_coo(&coo);
                let x: Vec<f32> = (0..p.k * n).map(|_| rng.normal() as f32).collect();
                let mut y = vec![f32::NAN; p.m * n];
                spmm(&p, &x, n, &mut y).unwrap();
                assert_close(&y, &reference(&p, &x, n), &format!("b={b} n={n}"));
            }
        }
    }

    #[test]
    fn generic_fallback_matches_reference() {
        let mut rng = Rng::seed_from_u64(0xFA11);
        for &b in &[1usize, 2, 5] {
            let mb = 9;
            let n = 19;
            let mask = patterns::uniform(mb * b, mb * b, b, mb * mb / 2, rng.next_u64()).unwrap();
            let coo = patterns::with_values(&mask, rng.next_u64());
            let p = PreparedBsr::from_coo(&coo);
            let x: Vec<f32> = (0..p.k * n).map(|_| rng.normal() as f32).collect();
            let mut y = vec![f32::NAN; p.m * n];
            spmm(&p, &x, n, &mut y).unwrap();
            assert_close(&y, &reference(&p, &x, n), &format!("b={b}"));
        }
    }

    #[test]
    fn f16_kernels_match_f32_oracle_on_quantized_operands() {
        // The FP16 contract end-to-end: quantize operands once, run
        // the F16 storage kernel, and compare against the f32 oracle
        // evaluated on the *same* quantized values — within the
        // documented f16 tolerance.
        let mut rng = Rng::seed_from_u64(0xF16);
        for &b in &[1usize, 4, 8, 16] {
            let mb = 6;
            let n = 33; // remainder tile included
            let mask = patterns::uniform(mb * b, mb * b, b, mb * mb / 3, rng.next_u64()).unwrap();
            let coo = patterns::with_values(&mask, rng.next_u64());
            let p16 = PreparedBsr::<F16>::from_coo(&coo);
            let xf: Vec<f32> = (0..p16.k * n).map(|_| rng.normal() as f32).collect();
            let x16: Vec<F16> = quantize(&xf);
            let mut y16 = vec![F16(0x7E00); p16.m * n]; // NaN garbage
            spmm(&p16, &x16, n, &mut y16).unwrap();
            // Oracle on the quantized operands: to_block_coo widens the
            // quantized weights; the x side widens the quantized x.
            let want =
                p16.to_block_coo().unwrap().spmm_dense(&dequantize(&x16), n).unwrap();
            for (i, (&u, &v)) in dequantize(&y16).iter().zip(&want).enumerate() {
                assert!(
                    close_enough_for(DType::Fp16, u, v),
                    "b={b}: element {i}: {u} vs {v}"
                );
            }
        }
    }

    #[test]
    fn dispatched_spmm_is_bit_identical_to_pinned_scalar() {
        // The module-level SIMD contract at unit scale (the broad
        // sweep lives in tests/kernels_differential.rs): whatever tier
        // `spmm` dispatched to produced the scalar path's bits.
        let mut rng = Rng::seed_from_u64(0x51D);
        for &b in &[4usize, 8, 16] {
            let mb = 5;
            let n = 33; // full tiles + remainder
            let mask = patterns::uniform(mb * b, mb * b, b, mb * mb / 3, rng.next_u64()).unwrap();
            let coo = patterns::with_values(&mask, rng.next_u64());
            let p = PreparedBsr::from_coo(&coo);
            let x: Vec<f32> = (0..p.k * n).map(|_| rng.normal() as f32).collect();
            let (mut y, mut y_ref) = (vec![f32::NAN; p.m * n], vec![f32::NAN; p.m * n]);
            spmm(&p, &x, n, &mut y).unwrap();
            spmm_scalar(&p, &x, n, &mut y_ref).unwrap();
            for (i, (&u, &v)) in y.iter().zip(&y_ref).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "b={b} elem {i}: {u} vs {v}");
            }
            let p16 = PreparedBsr::<F16>::from_coo(&coo);
            let x16: Vec<F16> = quantize(&x);
            let (mut y16, mut y16_ref) =
                (vec![F16(0x7E00); p16.m * n], vec![F16(0x7E00); p16.m * n]);
            spmm(&p16, &x16, n, &mut y16).unwrap();
            spmm_scalar(&p16, &x16, n, &mut y16_ref).unwrap();
            for (i, (&u, &v)) in y16.iter().zip(&y16_ref).enumerate() {
                assert_eq!(u.0, v.0, "f16 b={b} elem {i}");
            }
        }
    }

    #[test]
    fn tolerance_pairs_are_ordered() {
        let (r32, a32) = tolerance(DType::Fp32);
        let (r16, a16) = tolerance(DType::Fp16);
        assert!(r16 > r32 && a16 > a32, "f16 storage is contracted looser");
        assert!(close_enough_for(DType::Fp16, 1.0, 1.0005));
        assert!(!close_enough_for(DType::Fp32, 1.0, 1.0005));
    }

    #[test]
    fn empty_rows_are_zero_filled_without_prezeroing() {
        // One block at (0, 0) in a 3x3 grid: rows 1-2 must come out
        // zero even though y starts as NaN garbage.
        let coo = crate::sparse::coo::BlockCoo::new(
            12,
            12,
            4,
            vec![0],
            vec![0],
            vec![1.0; 16],
        )
        .unwrap();
        let p = PreparedBsr::from_coo(&coo);
        let n = 5;
        let x = vec![1f32; p.k * n];
        let mut y = vec![f32::NAN; p.m * n];
        spmm(&p, &x, n, &mut y).unwrap();
        assert!(y[..4 * n].iter().all(|&v| v == 4.0), "populated block-row");
        assert!(y[4 * n..].iter().all(|&v| v == 0.0), "empty block-rows zeroed");
        // Same invariant through the F16 instantiation.
        let p16 = PreparedBsr::<F16>::from_coo(&coo);
        let x16 = vec![F16::from_f32(1.0); p16.k * n];
        let mut y16 = vec![F16(0x7E00); p16.m * n];
        spmm(&p16, &x16, n, &mut y16).unwrap();
        assert!(y16[..4 * n].iter().all(|&v| v.to_f32() == 4.0));
        assert!(y16[4 * n..].iter().all(|&v| v == F16::ZERO));
    }

    #[test]
    fn operand_shape_errors_not_panics() {
        let coo = crate::sparse::coo::BlockCoo::new(4, 4, 2, vec![], vec![], vec![]).unwrap();
        let p = PreparedBsr::from_coo(&coo);
        let mut y = vec![0f32; 8];
        assert!(spmm(&p, &[0.0; 7], 2, &mut y).is_err());
        assert!(spmm(&p, &[0.0; 8], 2, &mut y[..7]).is_err());
        assert!(spmm(&p, &[0.0; 8], 2, &mut y).is_ok());
    }
}
