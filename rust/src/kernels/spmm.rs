//! Tiled block-sparse SpMM microkernels over [`PreparedBsr`].
//!
//! Layout of the computation (Gale et al.'s row-offset recipe, scaled
//! to one CPU core): the output is walked one block-row at a time; for
//! each block-row the batch dimension `n` is processed in fixed-width
//! tiles of [`N_TILE`] columns so the `b x N_TILE` accumulator panel
//! lives in registers across the whole block-row — every `x` row
//! segment is loaded once per block and reused across the block's `b`
//! output rows, and each output element is **written exactly once**
//! (block-rows with no blocks are zero-filled). Block sizes 4, 8 and
//! 16 are monomorphized via const generics so the inner loops have
//! compile-time trip counts and autovectorize; other block sizes take
//! a structurally identical generic fallback.
//!
//! Numerics: per output element, contributions accumulate in the same
//! (block, then intra-block column) order as the naive references
//! ([`crate::runtime::spmm_ref`], [`BlockCoo::spmm_dense`]), but the
//! tiled path does not skip explicit zeros inside blocks and keeps
//! partial sums in a register panel — agreement with the references is
//! therefore contracted to the documented tolerance
//! ([`close_enough`]), not bit-equality (DESIGN.md §5).
//!
//! [`BlockCoo::spmm_dense`]: crate::sparse::coo::BlockCoo::spmm_dense

use crate::error::{Error, Result};
use crate::kernels::prepared::PreparedBsr;

/// Batch-dimension tile width (f32 lanes) of the register accumulator
/// panel. 16 lanes = two AVX2 / one AVX-512 vector per accumulator
/// row; the `n % N_TILE` remainder takes a narrower epilogue.
pub const N_TILE: usize = 16;

/// Tolerance contract for comparing tiled/parallel kernel output
/// against the naive references: relative error per element, with an
/// absolute floor for near-zero outputs. Tiling reorders f32 partial
/// sums (and keeps them in registers), so oracle comparisons where a
/// tiled path is under test use this bound instead of bit-equality.
pub const REL_TOLERANCE: f32 = 1e-5;

/// Absolute floor companion to [`REL_TOLERANCE`].
pub const ABS_TOLERANCE: f32 = 1e-5;

/// Whether two f32 values agree within the documented kernel
/// tolerance: `|a - b| <= ABS_TOLERANCE + REL_TOLERANCE * max(|a|, |b|)`.
pub fn close_enough(a: f32, b: f32) -> bool {
    (a - b).abs() <= ABS_TOLERANCE + REL_TOLERANCE * a.abs().max(b.abs())
}

/// Validate SpMM operand shapes against the prepared matrix.
fn check_operands(p: &PreparedBsr, x: &[f32], n: usize, y: &[f32]) -> Result<()> {
    if x.len() != p.k * n {
        return Err(Error::InvalidFormat(format!(
            "x has {} elements, kernel needs {} x {n}",
            x.len(),
            p.k
        )));
    }
    if y.len() != p.m * n {
        return Err(Error::InvalidFormat(format!(
            "y has {} elements, kernel needs {} x {n}",
            y.len(),
            p.m
        )));
    }
    Ok(())
}

/// Single-threaded tiled SpMM: `y = A x` with `A` prepared, `x`
/// row-major `k x n`, `y` row-major `m x n`. Overwrites all of `y`
/// (no pre-zeroing needed).
pub fn spmm(p: &PreparedBsr, x: &[f32], n: usize, y: &mut [f32]) -> Result<()> {
    check_operands(p, x, n, y)?;
    spmm_rows(p, x, n, 0, p.mb(), y);
    Ok(())
}

/// Compute block-rows `[r0, r1)` into `y_panel`, the panel's own
/// output slice of length `(r1 - r0) * b * n`. Dispatches to the
/// block-size-specialized microkernel. This is the unit of work a
/// parallel panel executes; `spmm` is the single-panel case.
pub(crate) fn spmm_rows(
    p: &PreparedBsr,
    x: &[f32],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [f32],
) {
    debug_assert_eq!(y_panel.len(), (r1 - r0) * p.b * n);
    match p.b {
        4 => spmm_rows_b::<4>(p, x, n, r0, r1, y_panel),
        8 => spmm_rows_b::<8>(p, x, n, r0, r1, y_panel),
        16 => spmm_rows_b::<16>(p, x, n, r0, r1, y_panel),
        _ => spmm_rows_generic(p, x, n, r0, r1, y_panel),
    }
}

/// The monomorphized microkernel: `B` is a compile-time block size, so
/// the accumulator panel `[[f32; N_TILE]; B]` is a fixed-size stack
/// array and every inner loop has a constant trip count.
fn spmm_rows_b<const B: usize>(
    p: &PreparedBsr,
    x: &[f32],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [f32],
) {
    debug_assert_eq!(p.b, B);
    let bsz = B * B;
    for (ri, r) in (r0..r1).enumerate() {
        let (lo, hi) = (p.row_ptr[r] as usize, p.row_ptr[r + 1] as usize);
        let out = &mut y_panel[ri * B * n..(ri + 1) * B * n];
        if lo == hi {
            out.fill(0.0);
            continue;
        }
        let mut j = 0;
        while j + N_TILE <= n {
            let mut acc = [[0f32; N_TILE]; B];
            for blk in lo..hi {
                let c = p.cols[blk] as usize;
                let vals = &p.values[blk * bsz..(blk + 1) * bsz];
                for bc in 0..B {
                    let xrow = &x[(c * B + bc) * n + j..][..N_TILE];
                    for (br, acc_row) in acc.iter_mut().enumerate() {
                        let w = vals[br * B + bc];
                        for (a, &xv) in acc_row.iter_mut().zip(xrow) {
                            *a += w * xv;
                        }
                    }
                }
            }
            for (br, acc_row) in acc.iter().enumerate() {
                out[br * n + j..br * n + j + N_TILE].copy_from_slice(acc_row);
            }
            j += N_TILE;
        }
        if j < n {
            let rem = n - j;
            let mut acc = [[0f32; N_TILE]; B];
            for blk in lo..hi {
                let c = p.cols[blk] as usize;
                let vals = &p.values[blk * bsz..(blk + 1) * bsz];
                for bc in 0..B {
                    let xrow = &x[(c * B + bc) * n + j..][..rem];
                    for (br, acc_row) in acc.iter_mut().enumerate() {
                        let w = vals[br * B + bc];
                        for (a, &xv) in acc_row.iter_mut().zip(xrow) {
                            *a += w * xv;
                        }
                    }
                }
            }
            for (br, acc_row) in acc.iter().enumerate() {
                out[br * n + j..br * n + n].copy_from_slice(&acc_row[..rem]);
            }
        }
    }
}

/// Structurally identical fallback for block sizes without a
/// monomorphized kernel (`b = 1` unstructured patterns, odd sizes).
/// The accumulator panel is one reusable heap buffer per call — the
/// call covers a whole row range, so the allocation amortizes.
fn spmm_rows_generic(
    p: &PreparedBsr,
    x: &[f32],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [f32],
) {
    let b = p.b;
    let bsz = b * b;
    let mut acc = vec![0f32; b * N_TILE];
    for (ri, r) in (r0..r1).enumerate() {
        let (lo, hi) = (p.row_ptr[r] as usize, p.row_ptr[r + 1] as usize);
        let out = &mut y_panel[ri * b * n..(ri + 1) * b * n];
        if lo == hi {
            out.fill(0.0);
            continue;
        }
        let mut j = 0;
        while j < n {
            let tile = N_TILE.min(n - j);
            acc.fill(0.0);
            for blk in lo..hi {
                let c = p.cols[blk] as usize;
                let vals = &p.values[blk * bsz..(blk + 1) * bsz];
                for bc in 0..b {
                    let xrow = &x[(c * b + bc) * n + j..][..tile];
                    for br in 0..b {
                        let w = vals[br * b + bc];
                        let acc_row = &mut acc[br * N_TILE..br * N_TILE + tile];
                        for (a, &xv) in acc_row.iter_mut().zip(xrow) {
                            *a += w * xv;
                        }
                    }
                }
            }
            for br in 0..b {
                out[br * n + j..br * n + j + tile]
                    .copy_from_slice(&acc[br * N_TILE..br * N_TILE + tile]);
            }
            j += tile;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::patterns;
    use crate::util::Rng;

    fn reference(p: &PreparedBsr, x: &[f32], n: usize) -> Vec<f32> {
        p.to_block_coo().unwrap().spmm_dense(x, n).unwrap()
    }

    fn assert_close(a: &[f32], b: &[f32], context: &str) {
        assert_eq!(a.len(), b.len(), "{context}: length");
        for (i, (&u, &v)) in a.iter().zip(b).enumerate() {
            assert!(close_enough(u, v), "{context}: element {i}: {u} vs {v}");
        }
    }

    #[test]
    fn specialized_kernels_match_reference() {
        let mut rng = Rng::seed_from_u64(0xBEEF);
        for &b in &[4usize, 8, 16] {
            for &n in &[1usize, 16, 33] {
                let mb = 6;
                let mask =
                    patterns::uniform(mb * b, mb * b, b, mb * mb / 3, rng.next_u64()).unwrap();
                let coo = patterns::with_values(&mask, rng.next_u64());
                let p = PreparedBsr::from_coo(&coo);
                let x: Vec<f32> = (0..p.k * n).map(|_| rng.normal() as f32).collect();
                let mut y = vec![f32::NAN; p.m * n];
                spmm(&p, &x, n, &mut y).unwrap();
                assert_close(&y, &reference(&p, &x, n), &format!("b={b} n={n}"));
            }
        }
    }

    #[test]
    fn generic_fallback_matches_reference() {
        let mut rng = Rng::seed_from_u64(0xFA11);
        for &b in &[1usize, 2, 5] {
            let mb = 9;
            let n = 19;
            let mask = patterns::uniform(mb * b, mb * b, b, mb * mb / 2, rng.next_u64()).unwrap();
            let coo = patterns::with_values(&mask, rng.next_u64());
            let p = PreparedBsr::from_coo(&coo);
            let x: Vec<f32> = (0..p.k * n).map(|_| rng.normal() as f32).collect();
            let mut y = vec![f32::NAN; p.m * n];
            spmm(&p, &x, n, &mut y).unwrap();
            assert_close(&y, &reference(&p, &x, n), &format!("b={b}"));
        }
    }

    #[test]
    fn empty_rows_are_zero_filled_without_prezeroing() {
        // One block at (0, 0) in a 3x3 grid: rows 1-2 must come out
        // zero even though y starts as NaN garbage.
        let coo = crate::sparse::coo::BlockCoo::new(
            12,
            12,
            4,
            vec![0],
            vec![0],
            vec![1.0; 16],
        )
        .unwrap();
        let p = PreparedBsr::from_coo(&coo);
        let n = 5;
        let x = vec![1f32; p.k * n];
        let mut y = vec![f32::NAN; p.m * n];
        spmm(&p, &x, n, &mut y).unwrap();
        assert!(y[..4 * n].iter().all(|&v| v == 4.0), "populated block-row");
        assert!(y[4 * n..].iter().all(|&v| v == 0.0), "empty block-rows zeroed");
    }

    #[test]
    fn operand_shape_errors_not_panics() {
        let coo = crate::sparse::coo::BlockCoo::new(4, 4, 2, vec![], vec![], vec![]).unwrap();
        let p = PreparedBsr::from_coo(&coo);
        let mut y = vec![0f32; 8];
        assert!(spmm(&p, &[0.0; 7], 2, &mut y).is_err());
        assert!(spmm(&p, &[0.0; 8], 2, &mut y[..7]).is_err());
        assert!(spmm(&p, &[0.0; 8], 2, &mut y).is_ok());
    }
}
