//! Measured sparsity-roofline model: how close each kernel runs to
//! what this machine can physically deliver (DESIGN.md §5.1; the
//! model follows "The Sparsity Roofline", PAPERS.md).
//!
//! A roofline needs two machine numbers and one shape number:
//!
//! * **peak FLOP rate** — measured by timing the multiply–add chain
//!   probe in [`crate::kernels::simd`] at the active tier's width.
//!   The probe issues the kernels' exact arithmetic (separate mul +
//!   add, **no FMA** — the bit-exactness contract forbids fusing), so
//!   this is the ceiling these kernels can actually reach; a true FMA
//!   peak would be ~2x higher and unreachable by design.
//! * **streaming bandwidth** — measured by timing a wide streaming
//!   read over a buffer sized far beyond the last-level cache.
//! * **arithmetic intensity** — FLOPs per byte of *compulsory*
//!   traffic (every operand and output byte moved exactly once, i.e.
//!   a perfect-cache model). For BSR SpMM at block size `b` with
//!   `nnzb` populated blocks ([`spmm_traffic`]):
//!
//!   ```text
//!   flops = 2 * nnzb * b^2 * n
//!   bytes = nnzb * b^2 * es              (block values)
//!         + 4 * (nnzb + m/b + 1)         (u32 cols + row_ptr)
//!         + min(k/b, nnzb) * b * n * es  (x rows touched, read once)
//!         + m * n * es                   (output, written once)
//!   ```
//!
//!   where `es` is the element size of the storage dtype. Halving
//!   `es` (f16 storage) halves every value term while flops are
//!   unchanged — f16 intensity is ~2x f32's, which is the whole
//!   mechanism behind the paper's f16 crossover advantage; the f16
//!   widening arithmetic itself is free in this model because the
//!   lanes widen during the load ([`crate::kernels::simd`]) and the
//!   flop count is defined on the widened multiply–adds. Dense `ikj`
//!   ([`dense_traffic`]) is the classical `2mkn` over
//!   `(mk + kn + mn) * es`.
//!
//! The per-shape ceiling is then
//! `min(peak_gflops, intensity * peak_gbps)` — memory-bound below the
//! machine's balance point, compute-bound above — and a kernel's
//! %-of-roofline is its achieved GFLOP/s over that ceiling. The wall
//! bench (`repro bench wall`) reports all three per swept shape per
//! kernel; [`crate::engine::WallFeedback`] can arm the same model as
//! a physical floor under observed kernel walls (a wall faster than
//! the roofline permits is a measurement or model bug, counted, never
//! a gate).

use std::time::{Duration, Instant};

use crate::kernels::simd;
use crate::DType;

/// The two measured machine ceilings a roofline is drawn from, plus
/// the SIMD tier label they were measured at.
///
/// # Examples
///
/// Classification is pure math over the measured peaks — a machine
/// doing 100 GFLOP/s and 10 GB/s balances at 10 flop/byte:
///
/// ```
/// use popsparse::kernels::roofline::{dense_traffic, spmm_traffic, Bound, MachineRoofline};
/// use popsparse::DType;
///
/// let machine = MachineRoofline { peak_gflops: 100.0, peak_gbps: 10.0, tier: "avx2" };
/// // Dense 64^3 f32: 2*64^3 flops over 3*64^2*4 bytes = 10.67 flop/B.
/// let (bound, ceiling) = machine.classify(&dense_traffic(64, 64, 64, DType::Fp32));
/// assert_eq!((bound, ceiling), (Bound::Compute, 100.0));
/// // A sparse shape at lower intensity is memory-bound: the ceiling
/// // is intensity * bandwidth, below the compute peak.
/// let t = spmm_traffic(64, 64, 32, 16, 8, DType::Fp32);
/// let (bound, ceiling) = machine.classify(&t);
/// assert_eq!(bound, Bound::Memory);
/// assert!(ceiling < 100.0);
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MachineRoofline {
    /// Peak no-FMA multiply–add rate, GFLOP/s (== flop/ns).
    pub peak_gflops: f64,
    /// Peak streaming read bandwidth, GB/s (== byte/ns).
    pub peak_gbps: f64,
    /// [`simd::tier_label`] at measurement time.
    pub tier: &'static str,
}

impl MachineRoofline {
    /// The balance point in flop/byte: shapes below it are
    /// memory-bound, above it compute-bound.
    pub fn balance(&self) -> f64 {
        self.peak_gflops / self.peak_gbps
    }

    /// Classify a shape: its bound and its ceiling in GFLOP/s
    /// (`min(peak_gflops, intensity * peak_gbps)`).
    pub fn classify(&self, t: &Traffic) -> (Bound, f64) {
        let memory_ceiling = t.intensity() * self.peak_gbps;
        if memory_ceiling < self.peak_gflops {
            (Bound::Memory, memory_ceiling)
        } else {
            (Bound::Compute, self.peak_gflops)
        }
    }

    /// The roofline for `threads` cooperating workers under a linear
    /// compute-scaling assumption: `threads` x the single-core FLOP
    /// peak, **unchanged** bandwidth. Bandwidth is measured
    /// single-threaded and DRAM is shared, but one core often cannot
    /// saturate the memory controllers — so a parallel kernel's
    /// %-of-roofline may exceed 100% on memory-bound shapes. Parallel
    /// rows are reported for trend; the single-threaded arms carry
    /// the contract.
    pub fn scaled(&self, threads: usize) -> MachineRoofline {
        MachineRoofline {
            peak_gflops: self.peak_gflops * threads.max(1) as f64,
            peak_gbps: self.peak_gbps,
            tier: self.tier,
        }
    }
}

/// Which machine ceiling binds a shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    /// `intensity * peak_gbps < peak_gflops`: the shape cannot feed
    /// the FPU from memory fast enough.
    Memory,
    /// The FLOP peak binds first.
    Compute,
}

impl std::fmt::Display for Bound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Bound::Memory => "mem",
            Bound::Compute => "comp",
        })
    }
}

/// FLOPs and compulsory memory traffic of one kernel invocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Traffic {
    /// Multiply–adds counted as 2 ops each.
    pub flops: f64,
    /// Minimum bytes moved (perfect-cache model: every operand and
    /// output byte exactly once).
    pub bytes: f64,
}

impl Traffic {
    /// Arithmetic intensity, flop/byte.
    pub fn intensity(&self) -> f64 {
        self.flops / self.bytes
    }
}

/// Compulsory traffic of BSR SpMM `y = A x` (`A` is `m x k` at block
/// size `b` with `nnz_blocks` populated blocks, `x` is `k x n`) in
/// storage dtype `dtype`. See the module docs for the formula;
/// activation reuse is perfect (each touched `x` block-row read
/// once), so the intensity is an upper bound — achieved rates are
/// measured against the ceiling this produces, which only makes the
/// reported %-of-roofline conservative.
pub fn spmm_traffic(
    m: usize,
    k: usize,
    n: usize,
    b: usize,
    nnz_blocks: usize,
    dtype: DType,
) -> Traffic {
    let es = dtype.size() as f64;
    let (mb, kb) = (m / b, k / b);
    let bsq = (b * b) as f64;
    let flops = 2.0 * nnz_blocks as f64 * bsq * n as f64;
    let bytes = nnz_blocks as f64 * bsq * es
        + 4.0 * (nnz_blocks + mb + 1) as f64
        + (kb.min(nnz_blocks) * b * n) as f64 * es
        + (m * n) as f64 * es;
    Traffic { flops, bytes }
}

/// Compulsory traffic of structured N:M SpMM `y = A x` (`A` is
/// `m x k` with exactly `nm_n` nonzeros per `nm_m`-wide column group,
/// `x` is `k x n`) in storage dtype `dtype`:
///
/// ```text
/// flops = 2 * m * (k / M) * N * n
/// bytes = m * (k / M) * N * es          (packed values)
///       + m * (k / M) * ceil(N / 2)     (column-index nibbles)
///       + k * n * es                    (x read once — every group
///                                        touches its sliver, so the
///                                        whole activation streams)
///       + m * n * es                    (output, written once)
/// ```
///
/// The nibble metadata is the structural win over BSR at `b = 1`:
/// half a byte per nonzero versus a u32 coordinate per block.
pub fn nm_traffic(
    m: usize,
    k: usize,
    n: usize,
    nm_n: usize,
    nm_m: usize,
    dtype: DType,
) -> Traffic {
    let es = dtype.size() as f64;
    let groups_total = (m * (k / nm_m)) as f64;
    let flops = 2.0 * groups_total * nm_n as f64 * n as f64;
    let bytes = groups_total * nm_n as f64 * es
        + groups_total * nm_n.div_ceil(2) as f64
        + (k * n) as f64 * es
        + (m * n) as f64 * es;
    Traffic { flops, bytes }
}

/// Compulsory traffic of dense `y = A x` (`A` `m x k`, `x` `k x n`)
/// in storage dtype `dtype`: `2mkn` flops over `(mk + kn + mn) * es`
/// bytes.
pub fn dense_traffic(m: usize, k: usize, n: usize, dtype: DType) -> Traffic {
    let es = dtype.size() as f64;
    Traffic {
        flops: 2.0 * (m * k) as f64 * n as f64,
        bytes: ((m * k + k * n + m * n) as f64) * es,
    }
}

/// Measure this machine's roofline: the no-FMA FLOP peak (multiply–
/// add chain probe at the active SIMD tier, best rate over repeated
/// timed calls within `budget`) and streaming read bandwidth (timed
/// passes over a `bandwidth_bytes` buffer — size it well past the
/// last-level cache, e.g. 64 MiB, or smaller for smoke runs where an
/// in-cache "bandwidth" is acceptable noise). Each peak is the *best*
/// observed rate: interference only slows a sample down, so max is
/// the right estimator for a ceiling.
pub fn measure(budget: Duration, bandwidth_bytes: usize) -> MachineRoofline {
    MachineRoofline {
        peak_gflops: measure_flops(budget),
        peak_gbps: measure_bandwidth(budget, bandwidth_bytes),
        tier: simd::tier_label(),
    }
}

fn measure_flops(budget: Duration) -> f64 {
    let mut rounds = 1usize << 12;
    let mut best = 0.0f64;
    let deadline = Instant::now() + budget;
    loop {
        let t0 = Instant::now();
        let (flops, sink) = simd::flops_probe(rounds);
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(sink);
        if dt < 50e-6 {
            // Too short for the timer's granularity: grow the probe
            // (keeps the loop terminating even with a zero budget).
            rounds = rounds.saturating_mul(4);
            continue;
        }
        best = best.max(flops / dt / 1e9);
        if Instant::now() >= deadline {
            return best;
        }
    }
}

fn measure_bandwidth(budget: Duration, bytes: usize) -> f64 {
    let len = (bytes / 4).max(1024);
    let mut buf = vec![0f32; len];
    // Non-trivial contents: an all-zero freshly-mapped buffer can be
    // backed by copy-on-write zero pages, overstating bandwidth.
    super::fill_pseudo(&mut buf, 0xBA2D);
    let deadline = Instant::now() + budget;
    let mut best = 0.0f64;
    loop {
        let t0 = Instant::now();
        let sink = simd::bandwidth_probe(&buf);
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(sink);
        if dt > 0.0 {
            best = best.max((len * 4) as f64 / dt / 1e9);
        }
        if Instant::now() >= deadline {
            return best;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spmm_traffic_matches_hand_computation() {
        // m = k = 64, b = 16 (mb = kb = 4), 8 blocks, n = 32, f32:
        //   flops = 2 * 8 * 256 * 32                  = 131072
        //   bytes = 8*256*4 + 4*(8+4+1) + 4*16*32*4 + 64*32*4
        //         = 8192 + 52 + 8192 + 8192           = 24628
        let t = spmm_traffic(64, 64, 32, 16, 8, DType::Fp32);
        assert_eq!(t.flops, 131072.0);
        assert_eq!(t.bytes, 24628.0);
        // f16 halves every value term, metadata unchanged:
        //   4096 + 52 + 4096 + 4096 = 12340, flops identical.
        let t16 = spmm_traffic(64, 64, 32, 16, 8, DType::Fp16);
        assert_eq!(t16.flops, 131072.0);
        assert_eq!(t16.bytes, 12340.0);
        assert!(
            t16.intensity() > 1.9 * t.intensity(),
            "f16 storage nearly doubles intensity: {} vs {}",
            t16.intensity(),
            t.intensity()
        );
    }

    #[test]
    fn spmm_activation_term_caps_at_full_x() {
        // With more blocks than block-columns, x cannot be read less
        // than once in full: the activation term must stop growing.
        let few = spmm_traffic(64, 64, 32, 16, 3, DType::Fp32);
        let many = spmm_traffic(64, 64, 32, 16, 16, DType::Fp32);
        let x_bytes = (64 * 32 * 4) as f64;
        assert!(few.bytes < many.bytes);
        // many: activation term = min(4, 16) * 16 * 32 * 4 = full x.
        let expected = 16.0 * 256.0 * 4.0 + 4.0 * (16 + 4 + 1) as f64 + x_bytes + x_bytes;
        assert_eq!(many.bytes, expected);
    }

    #[test]
    fn nm_traffic_matches_hand_computation() {
        // m = k = 64, 2:4 (16 groups/row), n = 32, f32:
        //   flops = 2 * 64 * 16 * 2 * 32            = 131072
        //   bytes = 64*16*2*4 + 64*16*1 + 64*32*4 + 64*32*4
        //         = 8192 + 1024 + 8192 + 8192       = 25600
        let t = nm_traffic(64, 64, 32, 2, 4, DType::Fp32);
        assert_eq!(t.flops, 131072.0);
        assert_eq!(t.bytes, 25600.0);
        // f16 halves the value terms; the nibble metadata is fixed:
        //   4096 + 1024 + 4096 + 4096 = 13312, flops identical.
        let t16 = nm_traffic(64, 64, 32, 2, 4, DType::Fp16);
        assert_eq!(t16.flops, 131072.0);
        assert_eq!(t16.bytes, 13312.0);
        assert!(t16.intensity() > 1.8 * t.intensity());
        // 4:8 keeps the same density (and flops) with 2 nibble bytes
        // per 8-wide group — identical metadata per nonzero.
        let t48 = nm_traffic(64, 64, 32, 4, 8, DType::Fp32);
        assert_eq!(t48.flops, t.flops);
        assert_eq!(t48.bytes, t.bytes);
    }

    #[test]
    fn dense_traffic_matches_hand_computation() {
        // 2 * 64^3 = 524288 flops; (3 * 64^2) * 4 = 49152 bytes.
        let t = dense_traffic(64, 64, 64, DType::Fp32);
        assert_eq!(t.flops, 524288.0);
        assert_eq!(t.bytes, 49152.0);
        assert!((t.intensity() - 10.666_666).abs() < 1e-3);
    }

    #[test]
    fn classification_switches_at_the_balance_point() {
        let machine = MachineRoofline { peak_gflops: 100.0, peak_gbps: 10.0, tier: "test" };
        assert_eq!(machine.balance(), 10.0);
        // AI = 5 -> memory-bound, ceiling = 5 * 10 = 50 GFLOP/s.
        let low = Traffic { flops: 500.0, bytes: 100.0 };
        assert_eq!(machine.classify(&low), (Bound::Memory, 50.0));
        // AI = 20 -> compute-bound at the flat 100 GFLOP/s roof.
        let high = Traffic { flops: 2000.0, bytes: 100.0 };
        assert_eq!(machine.classify(&high), (Bound::Compute, 100.0));
        assert_eq!(format!("{}|{}", Bound::Memory, Bound::Compute), "mem|comp");
    }

    #[test]
    fn scaled_roofline_multiplies_compute_only() {
        let machine = MachineRoofline { peak_gflops: 50.0, peak_gbps: 10.0, tier: "test" };
        let par = machine.scaled(4);
        assert_eq!((par.peak_gflops, par.peak_gbps), (200.0, 10.0));
        assert_eq!(machine.scaled(0).peak_gflops, 50.0, "clamped to 1 thread");
    }

    #[test]
    fn measured_roofline_is_positive_and_labeled() {
        // Tiny budget + small buffer: this is a smoke of the probe
        // plumbing, not a credible measurement.
        let machine = measure(Duration::from_millis(5), 1 << 20);
        assert!(machine.peak_gflops > 0.0, "{machine:?}");
        assert!(machine.peak_gbps > 0.0, "{machine:?}");
        assert_eq!(machine.tier, simd::tier_label());
    }
}
