//! Native compute layer: the single numeric engine behind the runtime
//! interpreter, the engine backends and the coordinator's wall-time
//! serving arm (DESIGN.md §5).
//!
//! The reproduction's other layers optimize *simulated* cycles; this
//! one optimizes the wall clock this machine can actually measure.
//! Since PR 5 the whole layer is generic over the storage element
//! ([`Element`]: `f32`, or the in-repo software [`F16`]), with f32
//! accumulation everywhere — jobs execute in their declared
//! [`DType`](crate::DType) instead of silently widening to FP32.
//! Structure:
//!
//! * [`element`] — the [`Element`] trait and the software IEEE
//!   binary16 type ([`F16`]: bit-exact round-trip, RNE quantization).
//! * [`PreparedBsr`] — the prepared operand: CSR-style block-row
//!   pointers with per-row contiguous columns/values, converted once
//!   from [`BlockCoo`](crate::sparse::coo::BlockCoo) per (pattern,
//!   dtype) and cached alongside plans in the
//!   [`PlanCache`](crate::coordinator::PlanCache);
//!   [`PreparedOperand`] is the dtype-erased cached handle.
//! * [`spmm`] / [`spmm_parallel`] / [`spmm_auto`] — block-size-
//!   specialized, `n`-tiled SpMM microkernels (`b` ∈ {4, 8, 16}
//!   monomorphized, generic fallback elsewhere), with nnz-balanced
//!   row-panel parallelism over disjoint output slices.
//! * [`dense::matmul`] — the `ikj`-tiled dense kernel with a reusable
//!   caller-owned output buffer.
//! * [`nm`] — the structured N:M sparse format ([`PreparedNm`]:
//!   packed values + per-group column-index nibbles) and its SpMM
//!   microkernel family (2:4 / 4:8 monomorphized, generic fallback),
//!   same accumulation contract and panel parallelism (DESIGN.md
//!   §5.2).
//! * [`simd`] — arch-gated explicit SIMD tiers (AVX2 / AVX2+F16C on
//!   x86-64, runtime-detected) behind the same entry points, pinned
//!   **bit-identical** to the scalar fallback per dtype; the scalar
//!   loops stay mandatory and numerics-defining. [`spmm_scalar`] and
//!   [`dense::matmul_scalar`] bypass dispatch so the pin is provable.
//! * [`pool`] — the persistent kernel worker pool every parallel
//!   kernel dispatches through since PR 10: lazily-spawned parked
//!   workers, per-call job injection, epoch-tagged dynamic unit
//!   claiming (row-merge scheduling for skewed rows), zero
//!   steady-state thread spawns or allocations (DESIGN.md §5.3).
//! * [`roofline`] — the measured sparsity-roofline model: machine
//!   peak FLOP/s + streaming bandwidth ([`simd`]'s probes), per-shape
//!   arithmetic intensity and memory/compute bound, the ceiling the
//!   wall bench reports %-of-roofline against.
//! * [`Scratch`] — reusable per-dtype operand/output buffers so
//!   steady-state numeric execution allocates nothing in either
//!   precision.
//!
//! The naive triple loops ([`crate::runtime::spmm_ref`],
//! [`crate::runtime::dense_ref`],
//! [`BlockCoo::spmm_dense`](crate::sparse::coo::BlockCoo::spmm_dense))
//! stay exactly as they are — they are the differential oracle the
//! kernel tests compare against, under the documented per-dtype
//! tolerance ([`close_enough`], [`close_enough_for`]; the FP16
//! contract compares against the oracle on f16-quantized operands).
//! `repro bench wall` measures the paths side by side in both dtypes.

pub mod dense;
pub mod element;
pub mod nm;
pub mod parallel;
pub mod pool;
pub mod prepared;
pub mod roofline;
pub mod simd;
pub mod spmm;

pub use dense::{matmul_auto, matmul_parallel};
pub use element::{dequantize, quantize, Element, F16};
pub use nm::{
    nm_for_density, spmm_nm, spmm_nm_auto, spmm_nm_parallel, spmm_nm_parallel_scoped,
    spmm_nm_scalar, PreparedNm,
};
pub use parallel::{
    default_threads, dtype_floor_scale, min_flops_per_thread, parallel_engages, partition_panels,
    scoped_min_flops_per_thread, spmm_auto, spmm_parallel, spmm_parallel_scoped,
    MIN_FLOPS_PER_THREAD, POOL_MIN_FLOPS_PER_THREAD,
};
pub use pool::{KernelPool, PoolCounters};
pub use prepared::{PreparedBsr, PreparedOperand};
pub use roofline::MachineRoofline;
pub use simd::SimdTier;
pub use spmm::{
    close_enough, close_enough_for, spmm, spmm_scalar, tolerance, ABS_TOLERANCE,
    ABS_TOLERANCE_F16, N_TILE, REL_TOLERANCE, REL_TOLERANCE_F16,
};

use crate::util::Rng;

/// Fill a buffer with cheap deterministic pseudo-data in [-0.5, 0.5)
/// (operands for wall-time measurement — shared by [`Scratch`] and
/// the wall bench so their operand streams cannot drift). The f32
/// value stream is dtype-independent; narrow storage quantizes it
/// element-wise, so an FP16 buffer holds exactly the quantized view of
/// the FP32 one.
pub(crate) fn fill_pseudo<E: Element>(buf: &mut [E], seed: u64) {
    let mut rng = Rng::seed_from_u64(seed);
    for v in buf.iter_mut() {
        *v = E::from_f32((rng.next_u64() >> 40) as f32 / (1u64 << 24) as f32 - 0.5);
    }
}

/// Reusable operand/output buffers for repeated numeric executions in
/// one storage dtype. Buffers grow to the working-set size and stay
/// there; operand contents are deterministic pseudo-data (re-filled
/// only when a buffer is resized — the values feed wall-time
/// measurement, not a numeric contract).
#[derive(Debug, Default)]
pub struct TypedScratch<E: Element> {
    x: Vec<E>,
    a: Vec<E>,
    y: Vec<E>,
}

impl<E: Element> TypedScratch<E> {
    fn ensure(buf: &mut Vec<E>, len: usize, seed: u64) {
        if buf.len() != len {
            buf.clear();
            buf.resize(len, E::ZERO);
            fill_pseudo(buf, seed);
        }
    }

    /// The `k x n` activation operand and the `m x n` output buffer
    /// for an SpMM (disjoint borrows from one scratch).
    pub fn spmm_operands(&mut self, m: usize, k: usize, n: usize) -> (&[E], &mut [E]) {
        Self::ensure(&mut self.x, k * n, 1);
        if self.y.len() != m * n {
            self.y.clear();
            self.y.resize(m * n, E::ZERO);
        }
        (&self.x, &mut self.y)
    }

    /// The `m x k` weight operand, `k x n` activation operand and
    /// `m x n` output buffer for a dense matmul.
    pub fn dense_operands(&mut self, m: usize, k: usize, n: usize) -> (&[E], &[E], &mut [E]) {
        Self::ensure(&mut self.a, m * k, 2);
        Self::ensure(&mut self.x, k * n, 1);
        if self.y.len() != m * n {
            self.y.clear();
            self.y.resize(m * n, E::ZERO);
        }
        (&self.a, &self.x, &mut self.y)
    }

    /// The most recent output buffer (oracle checks in tests).
    pub fn output(&self) -> &[E] {
        &self.y
    }
}

/// Per-worker scratch covering both storage dtypes: one
/// [`TypedScratch`] each for f32 and f16, so a worker serving mixed-
/// precision traffic still allocates nothing at steady state (each
/// dtype's working set warms once and stays).
///
/// # Examples
///
/// ```
/// use popsparse::kernels::Scratch;
///
/// let mut s = Scratch::default();
/// // x is k*n, y is m*n; repeated same-shape calls reuse the buffers.
/// let (x, y) = s.spmm_operands(8, 8, 4);
/// assert_eq!((x.len(), y.len()), (32, 32));
/// // The f16 half is independent and warms separately.
/// let (x16, _) = s.fp16().spmm_operands(8, 8, 4);
/// assert_eq!(x16.len(), 32);
/// ```
#[derive(Debug, Default)]
pub struct Scratch {
    s32: TypedScratch<f32>,
    s16: TypedScratch<F16>,
}

impl Scratch {
    /// The f32 half (also behind the f32-flavoured convenience
    /// accessors below, which predate the dtype split).
    pub fn fp32(&mut self) -> &mut TypedScratch<f32> {
        &mut self.s32
    }

    /// The f16 half.
    pub fn fp16(&mut self) -> &mut TypedScratch<F16> {
        &mut self.s16
    }

    /// f32 SpMM operands (see [`TypedScratch::spmm_operands`]).
    pub fn spmm_operands(&mut self, m: usize, k: usize, n: usize) -> (&[f32], &mut [f32]) {
        self.s32.spmm_operands(m, k, n)
    }

    /// f32 dense operands (see [`TypedScratch::dense_operands`]).
    pub fn dense_operands(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
    ) -> (&[f32], &[f32], &mut [f32]) {
        self.s32.dense_operands(m, k, n)
    }

    /// The most recent f32 output buffer.
    pub fn output(&self) -> &[f32] {
        self.s32.output()
    }

    /// The most recent f16 output buffer.
    pub fn output_f16(&self) -> &[F16] {
        self.s16.output()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_reuses_buffers_across_same_shape_calls() {
        let mut s = Scratch::default();
        let (x1_ptr, x1_val) = {
            let (x, y) = s.spmm_operands(8, 8, 4);
            assert_eq!((x.len(), y.len()), (32, 32));
            (x.as_ptr(), x[0])
        };
        let (x, _) = s.spmm_operands(8, 8, 4);
        assert_eq!(x.as_ptr(), x1_ptr, "same shape must not reallocate");
        assert_eq!(x[0], x1_val, "same shape must not refill");
        // A different shape re-provisions.
        let (x, y) = s.spmm_operands(16, 8, 8);
        assert_eq!((x.len(), y.len()), (64, 128));
    }

    #[test]
    fn dense_operands_are_disjoint_and_sized() {
        let mut s = Scratch::default();
        let (a, x, y) = s.dense_operands(3, 4, 5);
        assert_eq!((a.len(), x.len(), y.len()), (12, 20, 15));
        assert!(a.iter().any(|&v| v != 0.0), "pseudo-data filled");
        y[0] = 7.0;
        assert_eq!(s.output()[0], 7.0);
    }

    #[test]
    fn dtype_halves_are_independent_and_quantization_consistent() {
        let mut s = Scratch::default();
        let x32 = s.fp32().spmm_operands(8, 8, 4).0.to_vec();
        let x16 = s.fp16().spmm_operands(8, 8, 4).0.to_vec();
        // Same deterministic f32 stream, quantized per dtype: the f16
        // operand is exactly the quantized view of the f32 one.
        for (a, b) in x32.iter().zip(&x16) {
            assert_eq!(F16::from_f32(*a), *b);
        }
        // Warming one half never perturbs the other.
        let again = s.fp32().spmm_operands(8, 8, 4).0.to_vec();
        assert_eq!(again, x32);
    }
}
