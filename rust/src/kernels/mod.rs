//! Native compute layer: the single numeric engine behind the runtime
//! interpreter, the engine backends and the coordinator's wall-time
//! serving arm (DESIGN.md §5).
//!
//! The reproduction's other layers optimize *simulated* cycles; this
//! one optimizes the wall clock this machine can actually measure.
//! Structure:
//!
//! * [`PreparedBsr`] — the prepared operand: CSR-style block-row
//!   pointers with per-row contiguous columns/values, converted once
//!   from [`BlockCoo`](crate::sparse::coo::BlockCoo) and cached per
//!   pattern alongside plans in the
//!   [`PlanCache`](crate::coordinator::PlanCache).
//! * [`spmm`] / [`spmm_parallel`] / [`spmm_auto`] — block-size-
//!   specialized, `n`-tiled SpMM microkernels (`b` ∈ {4, 8, 16}
//!   monomorphized, generic fallback elsewhere), with nnz-balanced
//!   row-panel parallelism over disjoint output slices.
//! * [`dense::matmul`] — the `ikj`-tiled dense kernel with a reusable
//!   caller-owned output buffer.
//! * [`Scratch`] — reusable operand/output buffers so steady-state
//!   numeric execution allocates nothing.
//!
//! The naive triple loops ([`crate::runtime::spmm_ref`],
//! [`crate::runtime::dense_ref`],
//! [`BlockCoo::spmm_dense`](crate::sparse::coo::BlockCoo::spmm_dense))
//! stay exactly as they are — they are the differential oracle the
//! kernel tests compare against, under the documented tolerance
//! ([`close_enough`]). `repro bench wall` measures all three paths
//! side by side.

pub mod dense;
pub mod parallel;
pub mod prepared;
pub mod spmm;

pub use parallel::{
    default_threads, partition_panels, spmm_auto, spmm_parallel, MIN_FLOPS_PER_THREAD,
};
pub use prepared::PreparedBsr;
pub use spmm::{close_enough, spmm, ABS_TOLERANCE, N_TILE, REL_TOLERANCE};

use crate::util::Rng;

/// Reusable operand/output buffers for repeated numeric executions.
/// Buffers grow to the working-set size and stay there; operand
/// contents are deterministic pseudo-data (re-filled only when a
/// buffer is resized — the values feed wall-time measurement, not a
/// numeric contract).
#[derive(Debug, Default)]
pub struct Scratch {
    x: Vec<f32>,
    a: Vec<f32>,
    y: Vec<f32>,
}

/// Fill a buffer with cheap deterministic pseudo-data in [-0.5, 0.5)
/// (operands for wall-time measurement — shared by [`Scratch`] and
/// the wall bench so their operand streams cannot drift).
pub(crate) fn fill_pseudo(buf: &mut [f32], seed: u64) {
    let mut rng = Rng::seed_from_u64(seed);
    for v in buf.iter_mut() {
        *v = (rng.next_u64() >> 40) as f32 / (1u64 << 24) as f32 - 0.5;
    }
}

impl Scratch {
    fn ensure(buf: &mut Vec<f32>, len: usize, seed: u64) {
        if buf.len() != len {
            buf.clear();
            buf.resize(len, 0.0);
            fill_pseudo(buf, seed);
        }
    }

    /// The `k x n` activation operand and the `m x n` output buffer
    /// for an SpMM (disjoint borrows from one scratch).
    pub fn spmm_operands(&mut self, m: usize, k: usize, n: usize) -> (&[f32], &mut [f32]) {
        Self::ensure(&mut self.x, k * n, 1);
        if self.y.len() != m * n {
            self.y.clear();
            self.y.resize(m * n, 0.0);
        }
        (&self.x, &mut self.y)
    }

    /// The `m x k` weight operand, `k x n` activation operand and
    /// `m x n` output buffer for a dense matmul.
    pub fn dense_operands(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
    ) -> (&[f32], &[f32], &mut [f32]) {
        Self::ensure(&mut self.a, m * k, 2);
        Self::ensure(&mut self.x, k * n, 1);
        if self.y.len() != m * n {
            self.y.clear();
            self.y.resize(m * n, 0.0);
        }
        (&self.a, &self.x, &mut self.y)
    }

    /// The most recent output buffer (oracle checks in tests).
    pub fn output(&self) -> &[f32] {
        &self.y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_reuses_buffers_across_same_shape_calls() {
        let mut s = Scratch::default();
        let (x1_ptr, x1_val) = {
            let (x, y) = s.spmm_operands(8, 8, 4);
            assert_eq!((x.len(), y.len()), (32, 32));
            (x.as_ptr(), x[0])
        };
        let (x, _) = s.spmm_operands(8, 8, 4);
        assert_eq!(x.as_ptr(), x1_ptr, "same shape must not reallocate");
        assert_eq!(x[0], x1_val, "same shape must not refill");
        // A different shape re-provisions.
        let (x, y) = s.spmm_operands(16, 8, 8);
        assert_eq!((x.len(), y.len()), (64, 128));
    }

    #[test]
    fn dense_operands_are_disjoint_and_sized() {
        let mut s = Scratch::default();
        let (a, x, y) = s.dense_operands(3, 4, 5);
        assert_eq!((a.len(), x.len(), y.len()), (12, 20, 15));
        assert!(a.iter().any(|&v| v != 0.0), "pseudo-data filled");
        y[0] = 7.0;
        assert_eq!(s.output()[0], 7.0);
    }
}
