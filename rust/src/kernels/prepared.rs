//! Prepared block-sparse operand: the layout the tiled kernels consume.
//!
//! [`crate::sparse::coo::BlockCoo`] is the *canonical* in-memory
//! format — a sorted coordinate list, convenient to build and
//! validate. The kernels instead want CSR-style block-row pointers
//! (so a row panel is one contiguous range of blocks, no coordinate
//! scan) with column indices and block values laid out contiguously
//! per block-row. [`PreparedBsr`] is that layout, converted **once**
//! per pattern and cached alongside plans in
//! [`PlanCache`](crate::coordinator::PlanCache) so steady-state
//! serving never re-converts (DESIGN.md §5).

use crate::error::{Error, Result};
use crate::sparse::coo::BlockCoo;
use crate::sparse::patterns;

/// A block-sparse matrix in kernel-ready block-CSR layout.
///
/// Invariants (established by every constructor): `row_ptr` has
/// `m / b + 1` monotone entries with `row_ptr[0] == 0` and
/// `row_ptr[mb] == cols.len()`; `cols[row_ptr[r]..row_ptr[r + 1]]`
/// are the block-columns of block-row `r`; `values` holds one
/// row-major `b x b` block per entry of `cols`, in the same order.
#[derive(Debug, Clone, PartialEq)]
pub struct PreparedBsr {
    /// Element-level rows.
    pub m: usize,
    /// Element-level cols.
    pub k: usize,
    /// Block size.
    pub b: usize,
    /// Block-row pointers, `m / b + 1` entries.
    pub row_ptr: Vec<u32>,
    /// Block-column index per non-zero block, grouped by block-row.
    pub cols: Vec<u32>,
    /// Block values, `b * b` per block, same order as `cols`.
    pub values: Vec<f32>,
}

impl PreparedBsr {
    /// Convert from the canonical sorted coordinate list. `BlockCoo`'s
    /// strict `(row, col)` ordering means the blocks are already
    /// grouped by row in column order, so the conversion is one
    /// counting pass plus two buffer copies — no re-sorting.
    pub fn from_coo(coo: &BlockCoo) -> Self {
        let mb = if coo.b == 0 { 0 } else { coo.m / coo.b };
        let mut row_ptr = vec![0u32; mb + 1];
        for &r in &coo.block_rows {
            row_ptr[r as usize + 1] += 1;
        }
        for r in 0..mb {
            row_ptr[r + 1] += row_ptr[r];
        }
        Self {
            m: coo.m,
            k: coo.k,
            b: coo.b,
            row_ptr,
            cols: coo.block_cols.clone(),
            values: coo.values.clone(),
        }
    }

    /// Convert from raw coordinate arrays (the runtime's artifact
    /// operands), which are **not** required to be sorted: blocks are
    /// stably counting-scattered into row groups, preserving the input
    /// order within each row. Row-sorted input — the `BlockCoo`
    /// contract, and what every committed artifact caller passes —
    /// takes a fast path: the values are already row-grouped, so the
    /// relayout degenerates to two bulk copies. Coordinates must
    /// already be validated against the `mb x kb` grid (the runtime's
    /// `check_coords` does).
    pub fn from_parts(
        m: usize,
        k: usize,
        b: usize,
        rows: &[i32],
        cols: &[i32],
        values: &[f32],
    ) -> Self {
        let mb = if b == 0 { 0 } else { m / b };
        let bsz = b * b;
        let mut row_ptr = vec![0u32; mb + 1];
        for &r in rows {
            row_ptr[r as usize + 1] += 1;
        }
        for r in 0..mb {
            row_ptr[r + 1] += row_ptr[r];
        }
        if rows.windows(2).all(|w| w[0] <= w[1]) {
            return Self {
                m,
                k,
                b,
                row_ptr,
                cols: cols.iter().map(|&c| c as u32).collect(),
                values: values.to_vec(),
            };
        }
        let mut next: Vec<u32> = row_ptr[..mb].to_vec();
        let mut out_cols = vec![0u32; rows.len()];
        let mut out_values = vec![0f32; values.len()];
        for (i, &r) in rows.iter().enumerate() {
            let slot = next[r as usize] as usize;
            next[r as usize] += 1;
            out_cols[slot] = cols[i] as u32;
            out_values[slot * bsz..(slot + 1) * bsz]
                .copy_from_slice(&values[i * bsz..(i + 1) * bsz]);
        }
        Self { m, k, b, row_ptr, cols: out_cols, values: out_values }
    }

    /// Realize a pattern family's operand from its parameters: the
    /// mask from `(m, k, b, density, seed)` and the values from the
    /// same seed — exactly the operand the simulated job describes.
    /// This is the conversion the plan cache's prepared-operand slot
    /// performs on a miss.
    pub fn from_pattern(m: usize, k: usize, b: usize, density: f64, seed: u64) -> Result<Self> {
        let mask = patterns::with_density(m, k, b, density, seed)?;
        Ok(Self::from_coo(&patterns::with_values(&mask, seed)))
    }

    /// Number of block-rows.
    pub fn mb(&self) -> usize {
        self.row_ptr.len() - 1
    }

    /// Number of non-zero blocks.
    pub fn nnz_blocks(&self) -> usize {
        self.cols.len()
    }

    /// Number of non-zero blocks in block-rows `[r0, r1)`.
    pub fn nnz_in_rows(&self, r0: usize, r1: usize) -> usize {
        (self.row_ptr[r1] - self.row_ptr[r0]) as usize
    }

    /// Approximate heap footprint in bytes (cache sizing aid).
    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.cols.len() * 4 + self.values.len() * 4
    }

    /// Recover the canonical coordinate form. Exact inverse of
    /// [`PreparedBsr::from_coo`]: the reconstructed `BlockCoo` is
    /// equal (coordinates, values, bit-for-bit) to the original —
    /// pinned by the round-trip property test.
    pub fn to_block_coo(&self) -> Result<BlockCoo> {
        let mut block_rows = Vec::with_capacity(self.cols.len());
        for r in 0..self.mb() {
            for _ in self.row_ptr[r]..self.row_ptr[r + 1] {
                block_rows.push(r as u32);
            }
        }
        BlockCoo::new(self.m, self.k, self.b, block_rows, self.cols.clone(), self.values.clone())
            .map_err(|e| Error::InvalidFormat(format!("prepared operand not canonical: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BlockCoo {
        // 3x3 block grid, b=2; blocks at (0,1), (2,0), (2,2); row 1 empty.
        BlockCoo::new(
            6,
            6,
            2,
            vec![0, 2, 2],
            vec![1, 0, 2],
            (1..=12).map(|v| v as f32).collect(),
        )
        .unwrap()
    }

    #[test]
    fn from_coo_builds_row_ptr() {
        let p = PreparedBsr::from_coo(&sample());
        assert_eq!(p.row_ptr, vec![0, 1, 1, 3]);
        assert_eq!(p.cols, vec![1, 0, 2]);
        assert_eq!(p.mb(), 3);
        assert_eq!(p.nnz_blocks(), 3);
        assert_eq!(p.nnz_in_rows(0, 1), 1);
        assert_eq!(p.nnz_in_rows(1, 2), 0);
        assert_eq!(p.nnz_in_rows(2, 3), 2);
    }

    #[test]
    fn round_trips_exactly() {
        let coo = sample();
        let back = PreparedBsr::from_coo(&coo).to_block_coo().unwrap();
        assert_eq!(coo, back);
    }

    #[test]
    fn from_parts_handles_unsorted_coordinates() {
        let coo = sample();
        // Shuffle the block order; from_parts must regroup by row.
        let rows = vec![2i32, 0, 2];
        let cols = vec![2i32, 1, 0];
        let mut values = vec![0f32; 12];
        values[0..4].copy_from_slice(coo.block(2));
        values[4..8].copy_from_slice(coo.block(0));
        values[8..12].copy_from_slice(coo.block(1));
        let p = PreparedBsr::from_parts(6, 6, 2, &rows, &cols, &values);
        assert_eq!(p.row_ptr, vec![0, 1, 1, 3]);
        // Row 2 keeps input order: col 2 (arrived first), then col 0.
        assert_eq!(p.cols, vec![1, 2, 0]);
        assert_eq!(&p.values[0..4], coo.block(0));
        assert_eq!(&p.values[4..8], coo.block(2));
        assert_eq!(&p.values[8..12], coo.block(1));
    }

    #[test]
    fn from_parts_sorted_fast_path_matches_scatter_semantics() {
        // Row-sorted (but not column-sorted) input takes the bulk-copy
        // fast path; the result must be exactly what the stable
        // scatter produces: input order preserved within each row.
        let rows = vec![0i32, 2, 2];
        let cols = vec![1i32, 2, 0];
        let values: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let p = PreparedBsr::from_parts(6, 6, 2, &rows, &cols, &values);
        assert_eq!(p.row_ptr, vec![0, 1, 1, 3]);
        assert_eq!(p.cols, vec![1, 2, 0]);
        assert_eq!(p.values, values);
    }

    #[test]
    fn from_pattern_matches_manual_conversion() {
        let mask = patterns::with_density(64, 64, 8, 0.25, 42).unwrap();
        let coo = patterns::with_values(&mask, 42);
        let p = PreparedBsr::from_pattern(64, 64, 8, 0.25, 42).unwrap();
        assert_eq!(p, PreparedBsr::from_coo(&coo));
        assert!(p.bytes() > 0);
    }

    #[test]
    fn empty_matrix_is_representable() {
        let coo = BlockCoo::new(4, 4, 2, vec![], vec![], vec![]).unwrap();
        let p = PreparedBsr::from_coo(&coo);
        assert_eq!(p.row_ptr, vec![0, 0, 0]);
        assert_eq!(p.to_block_coo().unwrap(), coo);
    }
}
