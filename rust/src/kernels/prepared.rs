//! Prepared block-sparse operand: the layout the tiled kernels consume.
//!
//! [`crate::sparse::coo::BlockCoo`] is the *canonical* in-memory
//! format — a sorted coordinate list, convenient to build and
//! validate. The kernels instead want CSR-style block-row pointers
//! (so a row panel is one contiguous range of blocks, no coordinate
//! scan) with column indices and block values laid out contiguously
//! per block-row. [`PreparedBsr`] is that layout, converted **once**
//! per realized pattern *and storage dtype* and cached alongside plans
//! in the [`PlanCache`](crate::coordinator::PlanCache) so steady-state
//! serving never re-converts (DESIGN.md §5).
//!
//! The struct is generic over the storage element
//! ([`Element`](crate::kernels::Element)): `PreparedBsr<f32>` is the
//! original layout, `PreparedBsr<F16>` stores every block value as
//! IEEE binary16 (quantized once, at conversion time — kernels never
//! re-round weights). [`PreparedOperand`] is the dtype-erased handle
//! the serving-side cache stores, keyed by
//! [`JobSpec::prepared_key`](crate::coordinator::request::JobSpec::prepared_key)
//! (which includes the dtype, so FP16 and FP32 traffic on the same
//! pattern each convert exactly once).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::kernels::element::{Element, F16};
use crate::kernels::nm::PreparedNm;
use crate::sparse::coo::BlockCoo;
use crate::sparse::patterns;
use crate::DType;

/// A block-sparse matrix in kernel-ready block-CSR layout, stored in
/// element type `E`.
///
/// Invariants (established by every constructor): `row_ptr` has
/// `m / b + 1` monotone entries with `row_ptr[0] == 0` and
/// `row_ptr[mb] == cols.len()`; `cols[row_ptr[r]..row_ptr[r + 1]]`
/// are the block-columns of block-row `r`; `values` holds one
/// row-major `b x b` block per entry of `cols`, in the same order.
///
/// # Examples
///
/// Convert the canonical coordinate format once, then run the tiled
/// kernel against it:
///
/// ```
/// use popsparse::kernels::{spmm, PreparedBsr};
/// use popsparse::sparse::coo::BlockCoo;
///
/// // One 2x2 block at block-coordinate (0, 0) of a 4x4 matrix.
/// let coo = BlockCoo::new(4, 4, 2, vec![0], vec![0], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
/// let p: PreparedBsr = PreparedBsr::from_coo(&coo);
/// assert_eq!(p.row_ptr, vec![0, 1, 1]); // block-row 1 is empty
/// assert_eq!((p.mb(), p.nnz_blocks()), (2, 1));
///
/// let n = 3;
/// let x = vec![1.0f32; p.k * n];
/// let mut y = vec![f32::NAN; p.m * n];
/// spmm(&p, &x, n, &mut y).unwrap();
/// assert_eq!(y[0], 3.0); // row 0: 1 + 2
/// assert_eq!(y[n], 7.0); // row 1: 3 + 4
/// assert!(y[2 * n..].iter().all(|&v| v == 0.0)); // empty block-row zero-filled
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct PreparedBsr<E: Element = f32> {
    /// Element-level rows.
    pub m: usize,
    /// Element-level cols.
    pub k: usize,
    /// Block size.
    pub b: usize,
    /// Block-row pointers, `m / b + 1` entries.
    pub row_ptr: Vec<u32>,
    /// Block-column index per non-zero block, grouped by block-row.
    pub cols: Vec<u32>,
    /// Block values, `b * b` per block, same order as `cols`
    /// (quantized once at conversion for narrow `E`).
    pub values: Vec<E>,
}

impl<E: Element> PreparedBsr<E> {
    /// Convert from the canonical sorted coordinate list. `BlockCoo`'s
    /// strict `(row, col)` ordering means the blocks are already
    /// grouped by row in column order, so the conversion is one
    /// counting pass plus two buffer copies — no re-sorting. Values
    /// quantize element-wise into `E` (identity for f32).
    pub fn from_coo(coo: &BlockCoo) -> Self {
        let mb = if coo.b == 0 { 0 } else { coo.m / coo.b };
        let mut row_ptr = vec![0u32; mb + 1];
        for &r in &coo.block_rows {
            row_ptr[r as usize + 1] += 1;
        }
        for r in 0..mb {
            row_ptr[r + 1] += row_ptr[r];
        }
        Self {
            m: coo.m,
            k: coo.k,
            b: coo.b,
            row_ptr,
            cols: coo.block_cols.clone(),
            values: coo.values.iter().map(|&v| E::from_f32(v)).collect(),
        }
    }

    /// Convert from raw coordinate arrays (the runtime's artifact
    /// operands), which are **not** required to be sorted: blocks are
    /// stably counting-scattered into row groups, preserving the input
    /// order within each row. Row-sorted input — the `BlockCoo`
    /// contract, and what every committed artifact caller passes —
    /// takes a fast path: the values are already row-grouped, so the
    /// relayout degenerates to a bulk quantizing copy. Coordinates
    /// must already be validated against the `mb x kb` grid (the
    /// runtime's `check_coords` does).
    pub fn from_parts(
        m: usize,
        k: usize,
        b: usize,
        rows: &[i32],
        cols: &[i32],
        values: &[f32],
    ) -> Self {
        let mb = if b == 0 { 0 } else { m / b };
        let bsz = b * b;
        let mut row_ptr = vec![0u32; mb + 1];
        for &r in rows {
            row_ptr[r as usize + 1] += 1;
        }
        for r in 0..mb {
            row_ptr[r + 1] += row_ptr[r];
        }
        if rows.windows(2).all(|w| w[0] <= w[1]) {
            return Self {
                m,
                k,
                b,
                row_ptr,
                cols: cols.iter().map(|&c| c as u32).collect(),
                values: values.iter().map(|&v| E::from_f32(v)).collect(),
            };
        }
        let mut next: Vec<u32> = row_ptr[..mb].to_vec();
        let mut out_cols = vec![0u32; rows.len()];
        let mut out_values = vec![E::ZERO; values.len()];
        for (i, &r) in rows.iter().enumerate() {
            let slot = next[r as usize] as usize;
            next[r as usize] += 1;
            out_cols[slot] = cols[i] as u32;
            for (dst, &src) in
                out_values[slot * bsz..(slot + 1) * bsz].iter_mut().zip(&values[i * bsz..])
            {
                *dst = E::from_f32(src);
            }
        }
        Self { m, k, b, row_ptr, cols: out_cols, values: out_values }
    }

    /// Realize a pattern family's operand from its parameters: the
    /// mask from `(m, k, b, density, seed)` and the values from the
    /// same seed — exactly the operand the simulated job describes.
    /// This is the conversion the plan cache's prepared-operand slot
    /// performs on a miss.
    pub fn from_pattern(m: usize, k: usize, b: usize, density: f64, seed: u64) -> Result<Self> {
        let mask = patterns::with_density(m, k, b, density, seed)?;
        Ok(Self::from_coo(&patterns::with_values(&mask, seed)))
    }

    /// Number of block-rows.
    pub fn mb(&self) -> usize {
        self.row_ptr.len() - 1
    }

    /// Number of non-zero blocks.
    pub fn nnz_blocks(&self) -> usize {
        self.cols.len()
    }

    /// Number of non-zero blocks in block-rows `[r0, r1)`.
    pub fn nnz_in_rows(&self, r0: usize, r1: usize) -> usize {
        (self.row_ptr[r1] - self.row_ptr[r0]) as usize
    }

    /// Approximate heap footprint in bytes (cache sizing aid) — an
    /// FP16 operand costs half an FP32 one's value storage.
    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.cols.len() * 4
            + self.values.len() * std::mem::size_of::<E>()
    }

    /// Recover the canonical coordinate form, widening values back to
    /// f32. For `E = f32` this is the exact inverse of
    /// [`PreparedBsr::from_coo`] (coordinates and values bit-for-bit —
    /// pinned by the round-trip property test); for `F16` the
    /// reconstructed values are the f16-quantized ones, which equal the
    /// originals exactly when those were f16-representable (the
    /// element round-trip property).
    pub fn to_block_coo(&self) -> Result<BlockCoo> {
        let mut block_rows = Vec::with_capacity(self.cols.len());
        for r in 0..self.mb() {
            for _ in self.row_ptr[r]..self.row_ptr[r + 1] {
                block_rows.push(r as u32);
            }
        }
        BlockCoo::new(
            self.m,
            self.k,
            self.b,
            block_rows,
            self.cols.clone(),
            self.values.iter().map(|&v| v.to_f32()).collect(),
        )
        .map_err(|e| Error::InvalidFormat(format!("prepared operand not canonical: {e}")))
    }
}

/// A dtype-erased shared prepared operand: what the serving-side
/// prepared cache stores and [`execute_kernel`] consumes. One variant
/// per supported storage dtype *and packed format* (block-CSR or
/// structured N:M); the job's [`DType`] and mode pick at dispatch.
///
/// [`execute_kernel`]: crate::engine::backends::execute_kernel
#[derive(Debug, Clone)]
pub enum PreparedOperand {
    F32(Arc<PreparedBsr<f32>>),
    F16(Arc<PreparedBsr<F16>>),
    NmF32(Arc<PreparedNm<f32>>),
    NmF16(Arc<PreparedNm<F16>>),
}

impl PreparedOperand {
    /// Realize a pattern in the requested storage dtype (the prepared
    /// cache's miss path).
    pub fn from_pattern(
        m: usize,
        k: usize,
        b: usize,
        density: f64,
        seed: u64,
        dtype: DType,
    ) -> Result<Self> {
        Ok(match dtype {
            DType::Fp32 => {
                PreparedOperand::F32(Arc::new(PreparedBsr::from_pattern(m, k, b, density, seed)?))
            }
            DType::Fp16 => {
                PreparedOperand::F16(Arc::new(PreparedBsr::from_pattern(m, k, b, density, seed)?))
            }
        })
    }

    /// Realize a structured N:M pattern in the requested storage dtype
    /// (the prepared cache's miss path for [`Mode::Nm`] jobs).
    ///
    /// [`Mode::Nm`]: crate::coordinator::request::Mode::Nm
    pub fn from_nm_pattern(
        m: usize,
        k: usize,
        nm_n: usize,
        nm_m: usize,
        seed: u64,
        dtype: DType,
    ) -> Result<Self> {
        Ok(match dtype {
            DType::Fp32 => PreparedOperand::NmF32(Arc::new(PreparedNm::from_pattern(
                m, k, nm_n, nm_m, seed,
            )?)),
            DType::Fp16 => PreparedOperand::NmF16(Arc::new(PreparedNm::from_pattern(
                m, k, nm_n, nm_m, seed,
            )?)),
        })
    }

    /// The storage dtype this operand holds.
    pub fn dtype(&self) -> DType {
        match self {
            PreparedOperand::F32(_) | PreparedOperand::NmF32(_) => DType::Fp32,
            PreparedOperand::F16(_) | PreparedOperand::NmF16(_) => DType::Fp16,
        }
    }

    /// The f32 block-CSR operand, if that is what this holds.
    pub fn as_f32(&self) -> Option<&Arc<PreparedBsr<f32>>> {
        match self {
            PreparedOperand::F32(p) => Some(p),
            _ => None,
        }
    }

    /// The f16 block-CSR operand, if that is what this holds.
    pub fn as_f16(&self) -> Option<&Arc<PreparedBsr<F16>>> {
        match self {
            PreparedOperand::F16(p) => Some(p),
            _ => None,
        }
    }

    /// The f32 N:M operand, if that is what this holds.
    pub fn as_nm_f32(&self) -> Option<&Arc<PreparedNm<f32>>> {
        match self {
            PreparedOperand::NmF32(p) => Some(p),
            _ => None,
        }
    }

    /// The f16 N:M operand, if that is what this holds.
    pub fn as_nm_f16(&self) -> Option<&Arc<PreparedNm<F16>>> {
        match self {
            PreparedOperand::NmF16(p) => Some(p),
            _ => None,
        }
    }

    /// Non-zero blocks for block-CSR operands; non-zero *elements* for
    /// N:M operands (whose granularity is element-level, `b == 1`) —
    /// in both cases the count of stored values over one block/element.
    pub fn nnz_blocks(&self) -> usize {
        match self {
            PreparedOperand::F32(p) => p.nnz_blocks(),
            PreparedOperand::F16(p) => p.nnz_blocks(),
            PreparedOperand::NmF32(p) => p.nnz(),
            PreparedOperand::NmF16(p) => p.nnz(),
        }
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        match self {
            PreparedOperand::F32(p) => p.bytes(),
            PreparedOperand::F16(p) => p.bytes(),
            PreparedOperand::NmF32(p) => p.bytes(),
            PreparedOperand::NmF16(p) => p.bytes(),
        }
    }

    /// Whether two handles share the same underlying allocation (cache
    /// identity checks in tests).
    pub fn ptr_eq(&self, other: &Self) -> bool {
        match (self, other) {
            (PreparedOperand::F32(a), PreparedOperand::F32(b)) => Arc::ptr_eq(a, b),
            (PreparedOperand::F16(a), PreparedOperand::F16(b)) => Arc::ptr_eq(a, b),
            (PreparedOperand::NmF32(a), PreparedOperand::NmF32(b)) => Arc::ptr_eq(a, b),
            (PreparedOperand::NmF16(a), PreparedOperand::NmF16(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BlockCoo {
        // 3x3 block grid, b=2; blocks at (0,1), (2,0), (2,2); row 1 empty.
        BlockCoo::new(
            6,
            6,
            2,
            vec![0, 2, 2],
            vec![1, 0, 2],
            (1..=12).map(|v| v as f32).collect(),
        )
        .unwrap()
    }

    #[test]
    fn from_coo_builds_row_ptr() {
        let p: PreparedBsr = PreparedBsr::from_coo(&sample());
        assert_eq!(p.row_ptr, vec![0, 1, 1, 3]);
        assert_eq!(p.cols, vec![1, 0, 2]);
        assert_eq!(p.mb(), 3);
        assert_eq!(p.nnz_blocks(), 3);
        assert_eq!(p.nnz_in_rows(0, 1), 1);
        assert_eq!(p.nnz_in_rows(1, 2), 0);
        assert_eq!(p.nnz_in_rows(2, 3), 2);
    }

    #[test]
    fn round_trips_exactly() {
        let coo = sample();
        let back = PreparedBsr::<f32>::from_coo(&coo).to_block_coo().unwrap();
        assert_eq!(coo, back);
        // Small integers are f16-representable, so the F16 layout
        // round-trips this sample exactly too — and at half the value
        // storage.
        let p16 = PreparedBsr::<F16>::from_coo(&coo);
        assert_eq!(p16.to_block_coo().unwrap(), coo);
        let p32 = PreparedBsr::<f32>::from_coo(&coo);
        assert!(p16.bytes() < p32.bytes());
    }

    #[test]
    fn f16_conversion_quantizes_once() {
        // A non-representable value is rounded at conversion; the
        // reconstructed coo carries the quantized value, not the
        // original.
        let v = 1.0 + f32::powi(2.0, -12); // rounds to 1.0 in f16
        let coo = BlockCoo::new(2, 2, 1, vec![0], vec![0], vec![v]).unwrap();
        let p16 = PreparedBsr::<F16>::from_coo(&coo);
        assert_eq!(p16.values[0], F16::from_f32(v));
        assert_eq!(p16.to_block_coo().unwrap().values[0], 1.0);
    }

    #[test]
    fn from_parts_handles_unsorted_coordinates() {
        let coo = sample();
        // Shuffle the block order; from_parts must regroup by row.
        let rows = vec![2i32, 0, 2];
        let cols = vec![2i32, 1, 0];
        let mut values = vec![0f32; 12];
        values[0..4].copy_from_slice(coo.block(2));
        values[4..8].copy_from_slice(coo.block(0));
        values[8..12].copy_from_slice(coo.block(1));
        let p: PreparedBsr = PreparedBsr::from_parts(6, 6, 2, &rows, &cols, &values);
        assert_eq!(p.row_ptr, vec![0, 1, 1, 3]);
        // Row 2 keeps input order: col 2 (arrived first), then col 0.
        assert_eq!(p.cols, vec![1, 2, 0]);
        assert_eq!(&p.values[0..4], coo.block(0));
        assert_eq!(&p.values[4..8], coo.block(2));
        assert_eq!(&p.values[8..12], coo.block(1));
        // The F16 scatter produces the same layout, quantized.
        let p16: PreparedBsr<F16> = PreparedBsr::from_parts(6, 6, 2, &rows, &cols, &values);
        assert_eq!(p16.row_ptr, p.row_ptr);
        assert_eq!(p16.cols, p.cols);
        assert_eq!(p16.values[0].to_f32(), p.values[0]);
    }

    #[test]
    fn from_parts_sorted_fast_path_matches_scatter_semantics() {
        // Row-sorted (but not column-sorted) input takes the bulk-copy
        // fast path; the result must be exactly what the stable
        // scatter produces: input order preserved within each row.
        let rows = vec![0i32, 2, 2];
        let cols = vec![1i32, 2, 0];
        let values: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let p: PreparedBsr = PreparedBsr::from_parts(6, 6, 2, &rows, &cols, &values);
        assert_eq!(p.row_ptr, vec![0, 1, 1, 3]);
        assert_eq!(p.cols, vec![1, 2, 0]);
        assert_eq!(p.values, values);
    }

    #[test]
    fn from_pattern_matches_manual_conversion() {
        let mask = patterns::with_density(64, 64, 8, 0.25, 42).unwrap();
        let coo = patterns::with_values(&mask, 42);
        let p: PreparedBsr = PreparedBsr::from_pattern(64, 64, 8, 0.25, 42).unwrap();
        assert_eq!(p, PreparedBsr::from_coo(&coo));
        assert!(p.bytes() > 0);
    }

    #[test]
    fn empty_matrix_is_representable() {
        let coo = BlockCoo::new(4, 4, 2, vec![], vec![], vec![]).unwrap();
        let p: PreparedBsr = PreparedBsr::from_coo(&coo);
        assert_eq!(p.row_ptr, vec![0, 0, 0]);
        assert_eq!(p.to_block_coo().unwrap(), coo);
    }

    #[test]
    fn prepared_operand_dispatches_on_dtype() {
        let p32 = PreparedOperand::from_pattern(32, 32, 8, 0.5, 1, DType::Fp32).unwrap();
        let p16 = PreparedOperand::from_pattern(32, 32, 8, 0.5, 1, DType::Fp16).unwrap();
        assert_eq!(p32.dtype(), DType::Fp32);
        assert_eq!(p16.dtype(), DType::Fp16);
        assert!(p32.as_f32().is_some() && p32.as_f16().is_none());
        assert!(p16.as_f16().is_some() && p16.as_f32().is_none());
        assert_eq!(p32.nnz_blocks(), p16.nnz_blocks());
        assert!(p16.bytes() < p32.bytes(), "f16 storage is the point");
        assert!(p32.ptr_eq(&p32.clone()));
        assert!(!p32.ptr_eq(&p16));
    }

    #[test]
    fn prepared_operand_carries_nm_format() {
        let n32 = PreparedOperand::from_nm_pattern(8, 8, 2, 4, 3, DType::Fp32).unwrap();
        let n16 = PreparedOperand::from_nm_pattern(8, 8, 2, 4, 3, DType::Fp16).unwrap();
        assert_eq!(n32.dtype(), DType::Fp32);
        assert_eq!(n16.dtype(), DType::Fp16);
        assert!(n32.as_nm_f32().is_some() && n32.as_f32().is_none());
        assert!(n16.as_nm_f16().is_some() && n16.as_f16().is_none());
        // 8x8 at 2:4 keeps 2 of every 4: 32 stored elements.
        assert_eq!(n32.nnz_blocks(), 32);
        assert_eq!(n16.nnz_blocks(), 32);
        assert!(n16.bytes() < n32.bytes(), "f16 storage is the point");
        assert!(n32.ptr_eq(&n32.clone()));
        assert!(!n32.ptr_eq(&n16));
        // Format never silently crosses: a BSR accessor on an N:M
        // handle (and vice versa) is None, not a widen.
        let b32 = PreparedOperand::from_pattern(8, 8, 1, 0.5, 3, DType::Fp32).unwrap();
        assert!(b32.as_nm_f32().is_none() && b32.as_f32().is_some());
        assert!(!b32.ptr_eq(&n32));
    }
}
