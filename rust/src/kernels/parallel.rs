//! Row-panel parallel SpMM: nnz-balanced panels executed on the
//! persistent kernel pool ([`crate::kernels::pool`]).
//!
//! Block-rows are partitioned into contiguous panels balanced by
//! **non-zero block count**, not row count — a row-skewed pattern
//! (most of the nnz piled into a few block-rows) would otherwise hand
//! one thread nearly all the work. [`partition_panels`] is the single
//! deterministic partitioner: unit boundaries are a pure function of
//! the operand and the thread budget. The pooled path oversubscribes
//! it ([`ROW_MERGE_OVERSUB`] units per thread) and lets workers claim
//! units dynamically — row-merge scheduling, so nobody idles on the
//! skew tail — while each panel still owns a disjoint slice of the
//! output and executes the same per-row microkernel as the
//! single-threaded path. The parallel result is therefore
//! element-for-element identical to [`spmm`]'s — in every storage
//! dtype, under any unit→worker assignment (partition decisions read
//! only the dtype-independent row structure). Panels flow through the
//! same SIMD dispatch as the single-threaded path
//! ([`crate::kernels::simd`]), and since every tier is bit-identical
//! to the scalar fallback, the parallel == single-threaded pin is
//! unaffected by which tier each machine selects.
//!
//! [`spmm_parallel_scoped`] keeps the legacy scoped-spawn dispatch as
//! the measured reference: the spawn-overhead wall arm times it
//! against pool injection, and the differential suite pins all three
//! dispatches (serial / scoped / pooled) bit-identical.

use std::cell::RefCell;
use std::sync::OnceLock;

use crate::error::Result;
use crate::kernels::element::Element;
use crate::kernels::pool::{self, SendPtr};
use crate::kernels::prepared::PreparedBsr;
use crate::kernels::spmm::{spmm, spmm_rows};
use crate::DType;

/// Minimum useful FLOPs per thread *for f32 storage* under the
/// retired scoped-spawn dispatch: per-call OS thread spawns cost tens
/// of microseconds, so parallelism only paid off in the millions of
/// FLOPs per thread. Kept as the documented legacy floor — the
/// spawn-overhead wall arm re-measures it and the differential suite
/// still drives the scoped reference path — but the auto kernels now
/// engage at [`POOL_MIN_FLOPS_PER_THREAD`].
pub const MIN_FLOPS_PER_THREAD: f64 = 4e6;

/// The pooled engagement floor for f32 storage. Re-derived from the
/// spawn-vs-inject microbench
/// ([`pool::measure_dispatch_overhead`]) via [`derived_floor_flops`]:
/// injection into the warm pool costs ~1–3 µs against ~30–60 µs for
/// scoped spawns, so the floor drops 16x — mid-size jobs that used to
/// run single-threaded now parallelize. The constant (rather than a
/// boot-time measurement) keeps engagement, and with it the `bench
/// ci` gate points (`parallel_floor/<dtype>`), bit-deterministic.
pub const POOL_MIN_FLOPS_PER_THREAD: f64 = 2.5e5;

/// Floor derivation: dispatch overhead must stay under ~2% of kernel
/// runtime, i.e. the kernel must run ≥ 50x the dispatch cost.
pub const DISPATCH_AMORTIZATION: f64 = 50.0;

/// Conservative scalar kernel throughput (FLOP per ns per thread)
/// used to convert amortized dispatch time into a FLOP floor.
pub const HOST_FLOPS_PER_NS: f64 = 2.0;

/// Work units generated per thread by the pooled dispatch: the
/// row-merge oversubscription factor. More units per thread means a
/// worker finishing its short rows merges into the remainder instead
/// of idling; unit boundaries stay deterministic (the partitioner
/// sees `threads * ROW_MERGE_OVERSUB` parts).
pub const ROW_MERGE_OVERSUB: usize = 4;

/// The dtype scaling shared by **every** engagement floor (this is
/// the one definition both [`min_flops_per_thread`] and the N:M auto
/// kernel resolve through — `tests` pins the call sites agree). F16
/// storage moves half the bytes per FLOP (~2x the arithmetic
/// intensity of f32 — see [`crate::kernels::roofline`]), so a given
/// FLOP count finishes sooner single-threaded and dispatch overhead
/// amortizes at half the f32 floor.
pub fn dtype_floor_scale(dtype: DType) -> f64 {
    match dtype {
        DType::Fp32 => 1.0,
        DType::Fp16 => 0.5,
    }
}

/// The pooled engagement floor scaled by storage dtype.
pub fn min_flops_per_thread(dtype: DType) -> f64 {
    POOL_MIN_FLOPS_PER_THREAD * dtype_floor_scale(dtype)
}

/// The legacy scoped-spawn floor scaled by the same dtype rule — what
/// the auto kernels enforced before the pool landed; the
/// spawn-overhead wall arm reports it next to the pooled floor so the
/// 16x drop stays visible (and asserted: pooled < scoped).
pub fn scoped_min_flops_per_thread(dtype: DType) -> f64 {
    MIN_FLOPS_PER_THREAD * dtype_floor_scale(dtype)
}

/// Convert a measured per-dispatch overhead (ns) into a FLOPs-per-
/// thread engagement floor: the work must out-run the dispatch by
/// [`DISPATCH_AMORTIZATION`] at [`HOST_FLOPS_PER_NS`] throughput.
/// Sanity anchor: the legacy ~40 µs scoped spawn yields exactly the
/// legacy 4e6 floor; a ~2.5 µs injection yields
/// [`POOL_MIN_FLOPS_PER_THREAD`].
pub fn derived_floor_flops(overhead_ns: f64) -> f64 {
    overhead_ns * DISPATCH_AMORTIZATION * HOST_FLOPS_PER_NS
}

/// Whether a job of `flops` total work should take the panel-parallel
/// path at `threads` workers for `dtype` storage: more than one thread
/// available and at least [`min_flops_per_thread`] of work per thread.
/// This single predicate defines the engagement boundary for every
/// auto kernel ([`spmm_auto`], [`crate::kernels::nm::spmm_nm_auto`],
/// [`crate::kernels::dense::matmul_auto`]).
pub fn parallel_engages(dtype: DType, flops: f64, threads: usize) -> bool {
    threads > 1 && flops >= min_flops_per_thread(dtype) * threads as f64
}

/// The thread count the parallel paths default to. Cached in a
/// `OnceLock`: this sits on every kernel dispatch, and
/// `available_parallelism` is a syscall on most platforms.
pub fn default_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS
        .get_or_init(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Partition block-rows `0..mb` into at most `parts` contiguous
/// panels with roughly equal non-zero block counts. Every block-row is
/// covered exactly once; panels are non-empty in rows (an all-zero
/// row span still needs its output zero-filled by someone). This is
/// the single deterministic partitioner behind every parallel kernel:
/// pooled dispatch changes which worker *runs* a panel, never where
/// the panel boundaries fall.
pub fn partition_panels<E: Element>(p: &PreparedBsr<E>, parts: usize) -> Vec<(usize, usize)> {
    let mut panels = Vec::new();
    partition_rows_balanced_into(
        &mut panels,
        p.mb(),
        p.nnz_blocks(),
        |r| p.nnz_in_rows(r, r + 1),
        parts,
    );
    panels
}

/// The partition core behind [`partition_panels`], shared with the
/// N:M kernels ([`crate::kernels::nm`]): greedy fair-share over any
/// row axis with a per-row nnz accessor.
pub(crate) fn partition_rows_balanced(
    rows: usize,
    total: usize,
    nnz_of_row: impl Fn(usize) -> usize,
    parts: usize,
) -> Vec<(usize, usize)> {
    let mut panels = Vec::new();
    partition_rows_balanced_into(&mut panels, rows, total, nnz_of_row, parts);
    panels
}

/// Allocation-reusing core: clears and fills `panels` in place, so
/// steady-state dispatch through the thread-local unit buffer
/// ([`with_merge_units`]) performs zero allocations once warm.
pub(crate) fn partition_rows_balanced_into(
    panels: &mut Vec<(usize, usize)>,
    rows: usize,
    total: usize,
    nnz_of_row: impl Fn(usize) -> usize,
    parts: usize,
) {
    panels.clear();
    let parts = parts.max(1);
    if rows == 0 {
        return;
    }
    if parts == 1 || total == 0 {
        panels.push((0, rows));
        return;
    }
    let mut start = 0usize;
    let mut acc = 0usize;
    let mut assigned = 0usize;
    for r in 0..rows {
        acc += nnz_of_row(r);
        let panels_left = parts - panels.len();
        // Close this panel once it holds its fair share of the still
        // unassigned nnz (ceil, so trailing panels never starve), as
        // long as at least one panel slot remains for the tail.
        let fair = (total - assigned).div_ceil(panels_left);
        if panels_left > 1 && acc >= fair.max(1) {
            panels.push((start, r + 1));
            assigned += acc;
            acc = 0;
            start = r + 1;
        }
    }
    if start < rows {
        panels.push((start, rows));
    }
}

thread_local! {
    /// Per-thread reusable unit buffer for pooled dispatch. Grows to
    /// the largest `threads * ROW_MERGE_OVERSUB` seen, then every
    /// later dispatch partitions into warm capacity — zero
    /// steady-state allocations (pinned by `tests/hot_path_alloc.rs`).
    static MERGE_UNITS: RefCell<Vec<(usize, usize)>> = const { RefCell::new(Vec::new()) };
}

/// Partition a row axis into oversubscribed row-merge units in the
/// calling thread's reusable buffer and hand the unit list to `f`.
/// Shared by the BSR, N:M and dense pooled kernels, so all three
/// dispatch through the same deterministic partitioner and the same
/// warm buffer. Not reentrant (the kernel layer never nests parallel
/// dispatches; a pool worker runs row bodies only).
pub(crate) fn with_merge_units<R>(
    rows: usize,
    total: usize,
    nnz_of_row: impl Fn(usize) -> usize,
    threads: usize,
    f: impl FnOnce(&[(usize, usize)]) -> R,
) -> R {
    MERGE_UNITS.with(|cell| {
        let mut buf = cell.borrow_mut();
        partition_rows_balanced_into(
            &mut buf,
            rows,
            total,
            nnz_of_row,
            threads.max(1).saturating_mul(ROW_MERGE_OVERSUB),
        );
        f(&buf)
    })
}

/// Parallel tiled SpMM: `y = A x` across nnz-balanced row-merge units
/// on the persistent kernel pool ([`crate::kernels::pool`]). Falls
/// back to the single-threaded kernel when one unit results.
/// Overwrites all of `y`. Bit-identical to [`spmm`] (disjoint panel
/// outputs, same per-row body — see the module doc).
pub fn spmm_parallel<E: Element>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    y: &mut [E],
    threads: usize,
) -> Result<()> {
    // Pre-check shapes once; panel slices below are then in-bounds by
    // construction (panels cover 0..mb exactly).
    if x.len() != p.k * n || y.len() != p.m * n {
        return spmm(p, x, n, y); // reuse the single-thread shape error
    }
    with_merge_units(p.mb(), p.nnz_blocks(), |r| p.nnz_in_rows(r, r + 1), threads, |units| {
        if units.len() <= 1 || threads <= 1 {
            return spmm(p, x, n, y);
        }
        let b = p.b;
        let base = SendPtr(y.as_mut_ptr());
        pool::global().run(units.len(), &|u| {
            let (r0, r1) = units[u];
            // SAFETY: units are disjoint, contiguous spans of
            // 0..mb, so each claimed unit writes a disjoint
            // sub-slice of `y`; the injector blocks until every
            // unit completes, keeping the borrow alive.
            let panel = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(r0 * b * n), (r1 - r0) * b * n)
            };
            spmm_rows(p, x, n, r0, r1, panel);
        });
        Ok(())
    })
}

/// The legacy scoped-spawn dispatch, retained as the measured and
/// differential reference for the pooled path (it spawns OS threads
/// per call — the spawn tax the pool exists to kill). Bit-identical
/// to both [`spmm`] and [`spmm_parallel`].
pub fn spmm_parallel_scoped<E: Element>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    y: &mut [E],
    threads: usize,
) -> Result<()> {
    let panels = partition_panels(p, threads);
    if panels.len() <= 1 {
        return spmm(p, x, n, y);
    }
    if x.len() != p.k * n || y.len() != p.m * n {
        return spmm(p, x, n, y); // reuse the single-thread shape error
    }
    std::thread::scope(|scope| {
        let mut rest: &mut [E] = y;
        for &(r0, r1) in &panels {
            let (panel, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * p.b * n);
            rest = tail;
            scope.spawn(move || spmm_rows(p, x, n, r0, r1, panel));
        }
    });
    Ok(())
}

/// SpMM with automatic parallelism: takes the pooled panel-parallel
/// path when the job clears the dtype-scaled engagement floor
/// ([`POOL_MIN_FLOPS_PER_THREAD`] per thread — 16x lower than the
/// scoped-spawn era now that dispatch is an injection, not a spawn),
/// the single-threaded tiled kernel otherwise. Either way the result
/// is bit-identical to [`spmm`]'s (and therefore to the pinned scalar
/// path's).
///
/// # Examples
///
/// ```
/// use popsparse::kernels::{spmm_auto, PreparedBsr};
/// use popsparse::sparse::coo::BlockCoo;
///
/// let coo = BlockCoo::new(4, 4, 2, vec![0], vec![0], vec![1.0; 4]).unwrap();
/// let p: PreparedBsr = PreparedBsr::from_coo(&coo);
/// let x = vec![1.0f32; 4 * 2];
/// let mut y = vec![f32::NAN; 4 * 2];
/// // Tiny job: stays single-threaded regardless of the budget.
/// spmm_auto(&p, &x, 2, &mut y, 8).unwrap();
/// assert_eq!(&y[..2], &[2.0, 2.0]);
/// ```
pub fn spmm_auto<E: Element>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    y: &mut [E],
    threads: usize,
) -> Result<()> {
    let flops = 2.0 * p.nnz_blocks() as f64 * (p.b * p.b) as f64 * n as f64;
    if parallel_engages(E::DTYPE, flops, threads) {
        spmm_parallel(p, x, n, y, threads)
    } else {
        spmm(p, x, n, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::element::{quantize, F16};
    use crate::sparse::patterns;
    use crate::util::Rng;

    #[test]
    fn panels_cover_rows_exactly_once() {
        let mask = patterns::uniform(64, 64, 4, 100, 3).unwrap();
        let p: PreparedBsr = PreparedBsr::from_coo(&patterns::with_values(&mask, 3));
        for parts in [1usize, 2, 3, 7, 100] {
            let panels = partition_panels(&p, parts);
            assert!(panels.len() <= parts.max(1));
            assert_eq!(panels.first().unwrap().0, 0);
            assert_eq!(panels.last().unwrap().1, p.mb());
            for w in panels.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous: {panels:?}");
                assert!(w[0].0 < w[0].1, "non-empty row span");
            }
        }
    }

    #[test]
    fn panels_balance_nnz_under_row_skew() {
        // Heavy skew: the balanced partition must not put most of the
        // nnz into one panel the way an equal-row split would.
        let mask = patterns::row_imbalanced(256, 256, 4, 512, 2.5, 9).unwrap();
        let p: PreparedBsr = PreparedBsr::from_coo(&patterns::with_values(&mask, 9));
        let panels = partition_panels(&p, 4);
        assert!(panels.len() >= 2);
        let max_nnz =
            panels.iter().map(|&(r0, r1)| p.nnz_in_rows(r0, r1)).max().unwrap();
        // Fair share is total/4; a skew-blind split of this pattern
        // puts far more than half the nnz in the heaviest quarter.
        assert!(
            max_nnz <= p.nnz_blocks() / 2,
            "heaviest panel {max_nnz} of {} blocks: {panels:?}",
            p.nnz_blocks()
        );
    }

    #[test]
    fn reusable_partition_matches_the_allocating_one() {
        let mask = patterns::row_imbalanced(256, 256, 4, 512, 2.5, 11).unwrap();
        let p: PreparedBsr = PreparedBsr::from_coo(&patterns::with_values(&mask, 11));
        let mut buf = vec![(7usize, 7usize); 3]; // stale content must be cleared
        for parts in [1usize, 2, 5, 16] {
            partition_rows_balanced_into(
                &mut buf,
                p.mb(),
                p.nnz_blocks(),
                |r| p.nnz_in_rows(r, r + 1),
                parts,
            );
            assert_eq!(buf, partition_panels(&p, parts), "parts {parts}");
        }
    }

    #[test]
    fn merge_units_oversubscribe_the_thread_budget() {
        // A big uniform pattern at 4 threads must produce more than 4
        // units (the row-merge pool has spare units to claim), all
        // from the same deterministic partitioner.
        let mask = patterns::uniform(512, 512, 4, 2000, 5).unwrap();
        let p: PreparedBsr = PreparedBsr::from_coo(&patterns::with_values(&mask, 5));
        with_merge_units(p.mb(), p.nnz_blocks(), |r| p.nnz_in_rows(r, r + 1), 4, |units| {
            assert!(units.len() > 4, "expected oversubscription, got {} units", units.len());
            assert!(units.len() <= 4 * ROW_MERGE_OVERSUB);
            assert_eq!(units, &partition_panels(&p, 4 * ROW_MERGE_OVERSUB)[..]);
        });
    }

    #[test]
    fn parallel_matches_single_threaded_exactly() {
        let mut rng = Rng::seed_from_u64(77);
        let mask = patterns::row_imbalanced(128, 128, 8, 120, 1.5, 5).unwrap();
        let p = PreparedBsr::from_coo(&patterns::with_values(&mask, 5));
        let n = 21;
        let x: Vec<f32> = (0..p.k * n).map(|_| rng.normal() as f32).collect();
        let mut y1 = vec![f32::NAN; p.m * n];
        let mut y4 = vec![f32::NAN; p.m * n];
        let mut ys = vec![f32::NAN; p.m * n];
        spmm(&p, &x, n, &mut y1).unwrap();
        spmm_parallel(&p, &x, n, &mut y4, 4).unwrap();
        spmm_parallel_scoped(&p, &x, n, &mut ys, 4).unwrap();
        // Same per-row kernel, disjoint outputs: identical, not just
        // close — under either dispatch mechanism.
        assert_eq!(y1, y4);
        assert_eq!(y1, ys);
    }

    #[test]
    fn f16_parallel_matches_f16_single_threaded_bit_exactly() {
        // The exactness argument is dtype-independent: panels run the
        // same microkernel on disjoint outputs, so the F16 parallel
        // result equals the F16 single-threaded result bit-for-bit.
        let mut rng = Rng::seed_from_u64(0xF1);
        let mask = patterns::row_imbalanced(128, 128, 8, 120, 1.5, 6).unwrap();
        let p = PreparedBsr::<F16>::from_coo(&patterns::with_values(&mask, 6));
        let n = 21;
        let xf: Vec<f32> = (0..p.k * n).map(|_| rng.normal() as f32).collect();
        let x: Vec<F16> = quantize(&xf);
        let mut y1 = vec![F16(0x7E00); p.m * n];
        let mut y4 = vec![F16(0x7E00); p.m * n];
        spmm(&p, &x, n, &mut y1).unwrap();
        spmm_parallel(&p, &x, n, &mut y4, 4).unwrap();
        assert_eq!(y1, y4);
    }

    #[test]
    fn engagement_boundary_is_dtype_scaled() {
        // The f16 floor is exactly half the f32 floor (the shared
        // dtype_floor_scale rule), so a job at half the f32 floor per
        // thread engages in f16 but not f32. Pinned at the exact
        // boundary (>= semantics) for both dtypes, at the *pooled*
        // floor — 16x below the legacy scoped-spawn floor.
        assert_eq!(min_flops_per_thread(DType::Fp32), 2.5e5);
        assert_eq!(min_flops_per_thread(DType::Fp16), 1.25e5);
        let threads = 8;
        let half = min_flops_per_thread(DType::Fp16) * threads as f64;
        let full = min_flops_per_thread(DType::Fp32) * threads as f64;
        assert!(parallel_engages(DType::Fp16, half, threads));
        assert!(!parallel_engages(DType::Fp32, half, threads));
        assert!(parallel_engages(DType::Fp32, full, threads));
        assert!(parallel_engages(DType::Fp16, full, threads));
        // Just below each floor stays single-threaded.
        assert!(!parallel_engages(DType::Fp16, half - 1.0, threads));
        assert!(!parallel_engages(DType::Fp32, full - 1.0, threads));
        // One thread never engages regardless of work.
        assert!(!parallel_engages(DType::Fp16, 1e12, 1));
    }

    #[test]
    fn pooled_floor_sits_strictly_below_the_scoped_floor_per_dtype() {
        for dtype in [DType::Fp32, DType::Fp16] {
            assert!(
                min_flops_per_thread(dtype) < scoped_min_flops_per_thread(dtype),
                "{dtype}: pooled floor must undercut the scoped-spawn floor"
            );
            // Both floors resolve through the one shared dtype rule.
            assert_eq!(
                min_flops_per_thread(dtype),
                POOL_MIN_FLOPS_PER_THREAD * dtype_floor_scale(dtype)
            );
            assert_eq!(
                scoped_min_flops_per_thread(dtype),
                MIN_FLOPS_PER_THREAD * dtype_floor_scale(dtype)
            );
        }
        // The derivation formula reproduces both anchors: ~40 µs
        // scoped spawn -> the legacy 4e6 floor, ~2.5 µs injection ->
        // the pooled floor.
        assert_eq!(derived_floor_flops(40_000.0), MIN_FLOPS_PER_THREAD);
        assert_eq!(derived_floor_flops(2_500.0), POOL_MIN_FLOPS_PER_THREAD);
    }

    #[test]
    fn default_threads_is_cached_and_stable() {
        let a = default_threads();
        let b = default_threads();
        assert!(a >= 1);
        assert_eq!(a, b);
    }

    #[test]
    fn auto_handles_tiny_and_empty_inputs() {
        let coo = crate::sparse::coo::BlockCoo::new(8, 8, 4, vec![], vec![], vec![]).unwrap();
        let p = PreparedBsr::from_coo(&coo);
        let x = vec![0f32; 8 * 3];
        let mut y = vec![f32::NAN; 8 * 3];
        spmm_auto(&p, &x, 3, &mut y, 8).unwrap();
        assert!(y.iter().all(|&v| v == 0.0));
    }
}
