//! Row-panel parallel SpMM: nnz-balanced panels over a scoped thread
//! pool.
//!
//! Block-rows are partitioned into contiguous panels balanced by
//! **non-zero block count**, not row count — a row-skewed pattern
//! (most of the nnz piled into a few block-rows) would otherwise hand
//! one thread nearly all the work. Each panel owns a disjoint slice of
//! the output (`split_at_mut`), so panels run with no reduction, no
//! locking and no false sharing on `y`; every panel executes the same
//! per-row microkernel as the single-threaded path, so the parallel
//! result is element-for-element identical to [`spmm`]'s — in every
//! storage dtype (the kernels are generic over
//! [`Element`](crate::kernels::Element); partition decisions read only
//! the dtype-independent row structure). Panels flow through the same
//! SIMD dispatch as the single-threaded path
//! ([`crate::kernels::simd`]), and since every tier is bit-identical
//! to the scalar fallback, the parallel == single-threaded pin is
//! unaffected by which tier each machine selects.

use crate::error::Result;
use crate::kernels::element::Element;
use crate::kernels::prepared::PreparedBsr;
use crate::kernels::spmm::{spmm, spmm_rows};
use crate::DType;

/// Minimum useful FLOPs per spawned panel *for f32 storage*: below
/// this the scoped thread spawn overhead (~tens of µs) outweighs the
/// work, so [`spmm_auto`] stays single-threaded. Narrow storage
/// engages earlier — see [`min_flops_per_thread`].
pub const MIN_FLOPS_PER_THREAD: f64 = 4e6;

/// The engagement floor scaled by storage dtype. F16 storage moves
/// half the bytes per FLOP (~2x the arithmetic intensity of f32 —
/// see [`crate::kernels::roofline`]), so a given FLOP count finishes
/// sooner single-threaded and the spawn overhead amortizes at half
/// the f32 floor; the f32 floor is the original, unchanged.
pub fn min_flops_per_thread(dtype: DType) -> f64 {
    match dtype {
        DType::Fp32 => MIN_FLOPS_PER_THREAD,
        DType::Fp16 => MIN_FLOPS_PER_THREAD / 2.0,
    }
}

/// Whether a job of `flops` total work should take the panel-parallel
/// path at `threads` workers for `dtype` storage: more than one thread
/// available and at least [`min_flops_per_thread`] of work per thread.
/// This single predicate defines the engagement boundary for every
/// auto kernel ([`spmm_auto`], [`crate::kernels::nm::spmm_nm_auto`]).
pub fn parallel_engages(dtype: DType, flops: f64, threads: usize) -> bool {
    threads > 1 && flops >= min_flops_per_thread(dtype) * threads as f64
}

/// The thread count the parallel paths default to.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Partition block-rows `0..mb` into at most `parts` contiguous
/// panels with roughly equal non-zero block counts. Every block-row is
/// covered exactly once; panels are non-empty in rows (an all-zero
/// row span still needs its output zero-filled by someone).
pub fn partition_panels<E: Element>(p: &PreparedBsr<E>, parts: usize) -> Vec<(usize, usize)> {
    partition_rows_balanced(p.mb(), p.nnz_blocks(), |r| p.nnz_in_rows(r, r + 1), parts)
}

/// The partition core behind [`partition_panels`], shared with the
/// N:M kernels ([`crate::kernels::nm`]): greedy fair-share over any
/// row axis with a per-row nnz accessor.
pub(crate) fn partition_rows_balanced(
    rows: usize,
    total: usize,
    nnz_of_row: impl Fn(usize) -> usize,
    parts: usize,
) -> Vec<(usize, usize)> {
    let parts = parts.max(1);
    if rows == 0 {
        return Vec::new();
    }
    if parts == 1 || total == 0 {
        return vec![(0, rows)];
    }
    let mut panels = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0usize;
    let mut assigned = 0usize;
    for r in 0..rows {
        acc += nnz_of_row(r);
        let panels_left = parts - panels.len();
        // Close this panel once it holds its fair share of the still
        // unassigned nnz (ceil, so trailing panels never starve), as
        // long as at least one panel slot remains for the tail.
        let fair = (total - assigned).div_ceil(panels_left);
        if panels_left > 1 && acc >= fair.max(1) {
            panels.push((start, r + 1));
            assigned += acc;
            acc = 0;
            start = r + 1;
        }
    }
    if start < rows {
        panels.push((start, rows));
    }
    panels
}

/// Parallel tiled SpMM: `y = A x` across nnz-balanced row panels on a
/// scoped thread pool. Falls back to the single-threaded kernel when
/// one panel results. Overwrites all of `y`.
pub fn spmm_parallel<E: Element>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    y: &mut [E],
    threads: usize,
) -> Result<()> {
    let panels = partition_panels(p, threads);
    if panels.len() <= 1 {
        return spmm(p, x, n, y);
    }
    // Pre-check shapes once; panel slices below are then in-bounds by
    // construction (panels cover 0..mb exactly).
    if x.len() != p.k * n || y.len() != p.m * n {
        return spmm(p, x, n, y); // reuse the single-thread shape error
    }
    std::thread::scope(|scope| {
        let mut rest: &mut [E] = y;
        for &(r0, r1) in &panels {
            let (panel, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * p.b * n);
            rest = tail;
            scope.spawn(move || spmm_rows(p, x, n, r0, r1, panel));
        }
    });
    Ok(())
}

/// SpMM with automatic parallelism: takes the panel-parallel path when
/// the job is big enough to amortize thread spawns
/// ([`MIN_FLOPS_PER_THREAD`] per thread), the single-threaded tiled
/// kernel otherwise. Either way the result is bit-identical to
/// [`spmm`]'s (and therefore to the pinned scalar path's).
///
/// # Examples
///
/// ```
/// use popsparse::kernels::{spmm_auto, PreparedBsr};
/// use popsparse::sparse::coo::BlockCoo;
///
/// let coo = BlockCoo::new(4, 4, 2, vec![0], vec![0], vec![1.0; 4]).unwrap();
/// let p: PreparedBsr = PreparedBsr::from_coo(&coo);
/// let x = vec![1.0f32; 4 * 2];
/// let mut y = vec![f32::NAN; 4 * 2];
/// // Tiny job: stays single-threaded regardless of the budget.
/// spmm_auto(&p, &x, 2, &mut y, 8).unwrap();
/// assert_eq!(&y[..2], &[2.0, 2.0]);
/// ```
pub fn spmm_auto<E: Element>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    y: &mut [E],
    threads: usize,
) -> Result<()> {
    let flops = 2.0 * p.nnz_blocks() as f64 * (p.b * p.b) as f64 * n as f64;
    if parallel_engages(E::DTYPE, flops, threads) {
        spmm_parallel(p, x, n, y, threads)
    } else {
        spmm(p, x, n, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::element::{quantize, F16};
    use crate::sparse::patterns;
    use crate::util::Rng;

    #[test]
    fn panels_cover_rows_exactly_once() {
        let mask = patterns::uniform(64, 64, 4, 100, 3).unwrap();
        let p: PreparedBsr = PreparedBsr::from_coo(&patterns::with_values(&mask, 3));
        for parts in [1usize, 2, 3, 7, 100] {
            let panels = partition_panels(&p, parts);
            assert!(panels.len() <= parts.max(1));
            assert_eq!(panels.first().unwrap().0, 0);
            assert_eq!(panels.last().unwrap().1, p.mb());
            for w in panels.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous: {panels:?}");
                assert!(w[0].0 < w[0].1, "non-empty row span");
            }
        }
    }

    #[test]
    fn panels_balance_nnz_under_row_skew() {
        // Heavy skew: the balanced partition must not put most of the
        // nnz into one panel the way an equal-row split would.
        let mask = patterns::row_imbalanced(256, 256, 4, 512, 2.5, 9).unwrap();
        let p: PreparedBsr = PreparedBsr::from_coo(&patterns::with_values(&mask, 9));
        let panels = partition_panels(&p, 4);
        assert!(panels.len() >= 2);
        let max_nnz =
            panels.iter().map(|&(r0, r1)| p.nnz_in_rows(r0, r1)).max().unwrap();
        // Fair share is total/4; a skew-blind split of this pattern
        // puts far more than half the nnz in the heaviest quarter.
        assert!(
            max_nnz <= p.nnz_blocks() / 2,
            "heaviest panel {max_nnz} of {} blocks: {panels:?}",
            p.nnz_blocks()
        );
    }

    #[test]
    fn parallel_matches_single_threaded_exactly() {
        let mut rng = Rng::seed_from_u64(77);
        let mask = patterns::row_imbalanced(128, 128, 8, 120, 1.5, 5).unwrap();
        let p = PreparedBsr::from_coo(&patterns::with_values(&mask, 5));
        let n = 21;
        let x: Vec<f32> = (0..p.k * n).map(|_| rng.normal() as f32).collect();
        let mut y1 = vec![f32::NAN; p.m * n];
        let mut y4 = vec![f32::NAN; p.m * n];
        spmm(&p, &x, n, &mut y1).unwrap();
        spmm_parallel(&p, &x, n, &mut y4, 4).unwrap();
        // Same per-row kernel, disjoint outputs: identical, not just
        // close.
        assert_eq!(y1, y4);
    }

    #[test]
    fn f16_parallel_matches_f16_single_threaded_bit_exactly() {
        // The exactness argument is dtype-independent: panels run the
        // same microkernel on disjoint outputs, so the F16 parallel
        // result equals the F16 single-threaded result bit-for-bit.
        let mut rng = Rng::seed_from_u64(0xF1);
        let mask = patterns::row_imbalanced(128, 128, 8, 120, 1.5, 6).unwrap();
        let p = PreparedBsr::<F16>::from_coo(&patterns::with_values(&mask, 6));
        let n = 21;
        let xf: Vec<f32> = (0..p.k * n).map(|_| rng.normal() as f32).collect();
        let x: Vec<F16> = quantize(&xf);
        let mut y1 = vec![F16(0x7E00); p.m * n];
        let mut y4 = vec![F16(0x7E00); p.m * n];
        spmm(&p, &x, n, &mut y1).unwrap();
        spmm_parallel(&p, &x, n, &mut y4, 4).unwrap();
        assert_eq!(y1, y4);
    }

    #[test]
    fn engagement_boundary_is_dtype_scaled() {
        // The f16 floor is exactly half the f32 floor, so a job at
        // 2e6 FLOPs/thread engages the pool in f16 but not f32, and a
        // job at the full 4e6 FLOPs/thread engages in both. Pinned at
        // the exact boundary (>= semantics) for both dtypes.
        assert_eq!(min_flops_per_thread(DType::Fp32), 4e6);
        assert_eq!(min_flops_per_thread(DType::Fp16), 2e6);
        let threads = 8;
        let half = 2e6 * threads as f64;
        let full = 4e6 * threads as f64;
        assert!(parallel_engages(DType::Fp16, half, threads));
        assert!(!parallel_engages(DType::Fp32, half, threads));
        assert!(parallel_engages(DType::Fp32, full, threads));
        assert!(parallel_engages(DType::Fp16, full, threads));
        // Just below each floor stays single-threaded.
        assert!(!parallel_engages(DType::Fp16, half - 1.0, threads));
        assert!(!parallel_engages(DType::Fp32, full - 1.0, threads));
        // One thread never engages regardless of work.
        assert!(!parallel_engages(DType::Fp16, 1e12, 1));
    }

    #[test]
    fn auto_handles_tiny_and_empty_inputs() {
        let coo = crate::sparse::coo::BlockCoo::new(8, 8, 4, vec![], vec![], vec![]).unwrap();
        let p = PreparedBsr::from_coo(&coo);
        let x = vec![0f32; 8 * 3];
        let mut y = vec![f32::NAN; 8 * 3];
        spmm_auto(&p, &x, 3, &mut y, 8).unwrap();
        assert!(y.iter().all(|&v| v == 0.0));
    }
}
