//! Explicit SIMD microkernels and the runtime tier dispatch that
//! selects them (DESIGN.md §5.1).
//!
//! The scalar tiled kernels in [`crate::kernels::spmm`] and
//! [`crate::kernels::dense`] stay the **mandatory fallback** — they
//! define the numerics, run on every architecture, and are the path
//! every other tier is pinned against. This module adds arch-gated
//! wide paths on top:
//!
//! * **`avx2`** (x86-64, runtime-detected): the b ∈ {4, 8, 16} SpMM
//!   microkernels and the `ikj` dense kernel with the `N_TILE = 16`
//!   accumulator panel held as two 8-lane `__m256` registers per
//!   output row.
//! * **`avx2+f16c`**: the same kernels for F16 storage with f16→f32
//!   widening done in vector lanes (`vcvtph2ps` on loads,
//!   `vcvtps2ph` round-to-nearest-even on the output store) instead
//!   of the software bit-twiddling path.
//!
//! **Bit-exactness contract.** Every SIMD path produces output
//! bit-identical to the scalar fallback for the same dtype (pinned by
//! `tests/kernels_differential.rs`), so the PR-6 replay and parity
//! contracts hold across machines with different tiers. The contract
//! falls out of three rules:
//!
//! 1. lanes are the batch dimension `j` — output columns are
//!    independent in the scalar accumulation, so vectorizing across
//!    them reorders nothing;
//! 2. each contribution is a separate f32 multiply then add
//!    (`_mm256_mul_ps` + `_mm256_add_ps`), never FMA — a fused
//!    multiply-add rounds once where the scalar code rounds twice —
//!    applied in the same (block, intra-block column) order per
//!    output element;
//! 3. f16 widening is value-exact on both paths (`vcvtph2ps` and the
//!    software [`F16::to_f32`] agree for every finite value and
//!    infinity; F16C ignores the MXCSR FTZ/DAZ bits), and the f16
//!    output store rounds nearest-even on both paths (`vcvtps2ph`
//!    with `_MM_FROUND_TO_NEAREST_INT` matches [`F16::from_f32`],
//!    subnormals and overflow-to-infinity included). The only
//!    documented divergence is signaling-NaN payloads (hardware
//!    quiets them); kernel operands are finite.
//!
//! **Selection rules** (the fallback is taken whenever any rule
//! fails): the element type must be exactly `f32` or [`F16`]
//! (checked by `TypeId`, not by trait metadata a third-party
//! [`Element`] impl could spoof); the block size must be one of the
//! monomorphized {4, 8, 16} (generic-`b` patterns stay scalar); the
//! CPU must report the tier's features at runtime
//! (`is_x86_feature_detected!`). Partial `n` tiles inside a selected
//! kernel run the *shared* scalar tile body, so the remainder path is
//! identical to the fallback by construction rather than by
//! duplication.
//!
//! The module also hosts the measurement probes the roofline model
//! ([`crate::kernels::roofline`]) times: a multiply–add chain probe at
//! the active tier's width (the kernels' no-FMA arithmetic, so the
//! measured peak is the ceiling *these* kernels can reach) and a
//! streaming-read probe for bandwidth.
//!
//! [`F16`]: crate::kernels::element::F16
//! [`F16::to_f32`]: crate::kernels::element::F16::to_f32
//! [`F16::from_f32`]: crate::kernels::element::F16::from_f32
//! [`Element`]: crate::kernels::element::Element

#[cfg(target_arch = "x86_64")]
use std::any::TypeId;

use crate::kernels::element::Element;
#[cfg(target_arch = "x86_64")]
use crate::kernels::element::F16;
use crate::kernels::nm::PreparedNm;
use crate::kernels::prepared::PreparedBsr;

/// The SIMD width tier the compute kernels dispatch at on this
/// machine, detected at runtime. `Scalar` is always available and is
/// the numerics-defining fallback; wider tiers are bit-identical
/// accelerations of it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdTier {
    /// Portable scalar loops (the autovectorizer may still widen
    /// them, but nothing is guaranteed).
    Scalar,
    /// 8-lane f32 vectors via AVX2 on x86-64.
    Avx2,
}

/// The compute tier active for f32 kernels on this machine.
pub fn tier() -> SimdTier {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            return SimdTier::Avx2;
        }
    }
    SimdTier::Scalar
}

/// Whether F16 storage kernels run with hardware f16↔f32 lane
/// conversion (requires `avx2` **and** `f16c`). When false, F16
/// kernels take the scalar path with software conversion.
pub fn f16_lanes() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        avx2() && f16c()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Human-readable tier label for reports: `"avx2+f16c"`, `"avx2"`, or
/// `"scalar"`.
pub fn tier_label() -> &'static str {
    match (tier(), f16_lanes()) {
        (SimdTier::Avx2, true) => "avx2+f16c",
        (SimdTier::Avx2, false) => "avx2",
        (SimdTier::Scalar, _) => "scalar",
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2() -> bool {
    // std caches the cpuid result; no need to cache again here.
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(target_arch = "x86_64")]
fn f16c() -> bool {
    std::arch::is_x86_feature_detected!("f16c")
}

#[cfg(target_arch = "x86_64")]
fn same_element<E: 'static, T: 'static>() -> bool {
    TypeId::of::<E>() == TypeId::of::<T>()
}

/// Reinterpret an element slice as its concrete type once `TypeId`
/// equality has been established. Safety: caller must have checked
/// `same_element::<E, T>()`; the cast is then the identity.
#[cfg(target_arch = "x86_64")]
unsafe fn cast_slice<E: Element, T: Element>(s: &[E]) -> &[T] {
    debug_assert!(same_element::<E, T>());
    std::slice::from_raw_parts(s.as_ptr().cast::<T>(), s.len())
}

#[cfg(target_arch = "x86_64")]
unsafe fn cast_slice_mut<E: Element, T: Element>(s: &mut [E]) -> &mut [T] {
    debug_assert!(same_element::<E, T>());
    std::slice::from_raw_parts_mut(s.as_mut_ptr().cast::<T>(), s.len())
}

#[cfg(target_arch = "x86_64")]
unsafe fn cast_prepared<E: Element, T: Element>(p: &PreparedBsr<E>) -> &PreparedBsr<T> {
    debug_assert!(same_element::<E, T>());
    &*(p as *const PreparedBsr<E>).cast::<PreparedBsr<T>>()
}

#[cfg(target_arch = "x86_64")]
unsafe fn cast_nm<E: Element, T: Element>(p: &PreparedNm<E>) -> &PreparedNm<T> {
    debug_assert!(same_element::<E, T>());
    &*(p as *const PreparedNm<E>).cast::<PreparedNm<T>>()
}

/// Try to run block-rows `[r0, r1)` through a SIMD tier. Returns
/// `false` (computing nothing) when the selection rules send this
/// call to the scalar fallback; on `true` the panel is fully written
/// and is bit-identical to what the fallback would have produced.
#[cfg(target_arch = "x86_64")]
pub(crate) fn try_spmm_rows<E: Element>(
    p: &PreparedBsr<E>,
    x: &[E],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [E],
) -> bool {
    if !matches!(p.b, 4 | 8 | 16) {
        return false;
    }
    if same_element::<E, f32>() && avx2() {
        unsafe {
            let p = cast_prepared::<E, f32>(p);
            let x = cast_slice::<E, f32>(x);
            let y = cast_slice_mut::<E, f32>(y_panel);
            spmm_rows_f32_avx2(p, x, n, r0, r1, y);
        }
        return true;
    }
    if same_element::<E, F16>() && avx2() && f16c() {
        unsafe {
            let p = cast_prepared::<E, F16>(p);
            let x = cast_slice::<E, F16>(x);
            let y = cast_slice_mut::<E, F16>(y_panel);
            spmm_rows_f16_avx2(p, x, n, r0, r1, y);
        }
        return true;
    }
    false
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn try_spmm_rows<E: Element>(
    _p: &PreparedBsr<E>,
    _x: &[E],
    _n: usize,
    _r0: usize,
    _r1: usize,
    _y_panel: &mut [E],
) -> bool {
    false
}

/// Try to run N:M rows `[r0, r1)` through a SIMD tier; same contract
/// as [`try_spmm_rows`]. Gated to the monomorphized group widths
/// `M` ∈ {4, 8} (other structures stay scalar, like generic-`b` BSR).
#[cfg(target_arch = "x86_64")]
pub(crate) fn try_spmm_nm_rows<E: Element>(
    p: &PreparedNm<E>,
    x: &[E],
    n: usize,
    r0: usize,
    r1: usize,
    y_panel: &mut [E],
) -> bool {
    if !matches!(p.nm_m, 4 | 8) {
        return false;
    }
    if same_element::<E, f32>() && avx2() {
        unsafe {
            let p = cast_nm::<E, f32>(p);
            let x = cast_slice::<E, f32>(x);
            let y = cast_slice_mut::<E, f32>(y_panel);
            nm_rows_f32_avx2(p, x, n, r0, r1, y);
        }
        return true;
    }
    if same_element::<E, F16>() && avx2() && f16c() {
        unsafe {
            let p = cast_nm::<E, F16>(p);
            let x = cast_slice::<E, F16>(x);
            let y = cast_slice_mut::<E, F16>(y_panel);
            nm_rows_f16_avx2(p, x, n, r0, r1, y);
        }
        return true;
    }
    false
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn try_spmm_nm_rows<E: Element>(
    _p: &PreparedNm<E>,
    _x: &[E],
    _n: usize,
    _r0: usize,
    _r1: usize,
    _y_panel: &mut [E],
) -> bool {
    false
}

/// Try to run the dense `ikj` kernel through a SIMD tier; same
/// contract as [`try_spmm_rows`]. Shapes are already validated by the
/// caller ([`crate::kernels::dense::matmul`]).
#[cfg(target_arch = "x86_64")]
pub(crate) fn try_matmul<E: Element>(
    a: &[E],
    x: &[E],
    m: usize,
    k: usize,
    n: usize,
    y: &mut [E],
) -> bool {
    if same_element::<E, f32>() && avx2() {
        unsafe {
            let a = cast_slice::<E, f32>(a);
            let x = cast_slice::<E, f32>(x);
            let y = cast_slice_mut::<E, f32>(y);
            matmul_f32_avx2(a, x, m, k, n, y);
        }
        return true;
    }
    if same_element::<E, F16>() && avx2() && f16c() {
        unsafe {
            let a = cast_slice::<E, F16>(a);
            let x = cast_slice::<E, F16>(x);
            let y = cast_slice_mut::<E, F16>(y);
            matmul_f16_avx2(a, x, m, k, n, y);
        }
        return true;
    }
    false
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn try_matmul<E: Element>(
    _a: &[E],
    _x: &[E],
    _m: usize,
    _k: usize,
    _n: usize,
    _y: &mut [E],
) -> bool {
    false
}

// ---------------------------------------------------------------------------
// AVX2 kernel bodies (x86-64 only).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use crate::kernels::dense::{dense_tile, I_TILE};
    use crate::kernels::element::F16;
    use crate::kernels::nm::{nm_tile, PreparedNm};
    use crate::kernels::prepared::PreparedBsr;
    use crate::kernels::spmm::{spmm_tile_b, N_TILE};

    /// `vcvtps2ph` rounding control: round-to-nearest-even, matching
    /// the software [`F16::from_f32`] path bit-for-bit.
    const RNE: i32 = _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC;

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn spmm_rows_f32_avx2(
        p: &PreparedBsr<f32>,
        x: &[f32],
        n: usize,
        r0: usize,
        r1: usize,
        y_panel: &mut [f32],
    ) {
        match p.b {
            4 => rows_f32::<4>(p, x, n, r0, r1, y_panel),
            8 => rows_f32::<8>(p, x, n, r0, r1, y_panel),
            16 => rows_f32::<16>(p, x, n, r0, r1, y_panel),
            _ => unreachable!("SIMD dispatch is gated to b in {{4, 8, 16}}"),
        }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "f16c")]
    pub(super) unsafe fn spmm_rows_f16_avx2(
        p: &PreparedBsr<F16>,
        x: &[F16],
        n: usize,
        r0: usize,
        r1: usize,
        y_panel: &mut [F16],
    ) {
        match p.b {
            4 => rows_f16::<4>(p, x, n, r0, r1, y_panel),
            8 => rows_f16::<8>(p, x, n, r0, r1, y_panel),
            16 => rows_f16::<16>(p, x, n, r0, r1, y_panel),
            _ => unreachable!("SIMD dispatch is gated to b in {{4, 8, 16}}"),
        }
    }

    /// The wide twin of `spmm_tile_b`'s full-tile case: the
    /// `B x N_TILE` accumulator panel as `[__m256; 2]` per output row,
    /// contributions applied as separate mul + add (no FMA) in the
    /// same (block, intra-block column) order as the scalar body.
    #[target_feature(enable = "avx2")]
    unsafe fn rows_f32<const B: usize>(
        p: &PreparedBsr<f32>,
        x: &[f32],
        n: usize,
        r0: usize,
        r1: usize,
        y_panel: &mut [f32],
    ) {
        let bsz = B * B;
        for (ri, r) in (r0..r1).enumerate() {
            let (lo, hi) = (p.row_ptr[r] as usize, p.row_ptr[r + 1] as usize);
            let out = &mut y_panel[ri * B * n..(ri + 1) * B * n];
            if lo == hi {
                out.fill(0.0);
                continue;
            }
            let mut j = 0;
            while j + N_TILE <= n {
                let mut acc = [[_mm256_setzero_ps(); 2]; B];
                for blk in lo..hi {
                    let c = p.cols[blk] as usize;
                    let vals = &p.values[blk * bsz..(blk + 1) * bsz];
                    for bc in 0..B {
                        let xp = x.as_ptr().add((c * B + bc) * n + j);
                        let x0 = _mm256_loadu_ps(xp);
                        let x1 = _mm256_loadu_ps(xp.add(8));
                        for (br, a) in acc.iter_mut().enumerate() {
                            let w = _mm256_set1_ps(vals[br * B + bc]);
                            a[0] = _mm256_add_ps(a[0], _mm256_mul_ps(w, x0));
                            a[1] = _mm256_add_ps(a[1], _mm256_mul_ps(w, x1));
                        }
                    }
                }
                for (br, a) in acc.iter().enumerate() {
                    let op = out.as_mut_ptr().add(br * n + j);
                    _mm256_storeu_ps(op, a[0]);
                    _mm256_storeu_ps(op.add(8), a[1]);
                }
                j += N_TILE;
            }
            if j < n {
                // Remainder columns run the shared scalar tile body —
                // identical to the fallback by construction.
                spmm_tile_b::<f32, B>(p, x, n, lo, hi, j, n - j, out);
            }
        }
    }

    /// F16 storage twin: widen in lanes (`vcvtph2ps`), accumulate in
    /// f32, store through `vcvtps2ph` round-to-nearest-even. Each
    /// block's weights are widened once per block into a stack panel
    /// (hardware conversion, value-exact vs the software path).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "f16c")]
    unsafe fn rows_f16<const B: usize>(
        p: &PreparedBsr<F16>,
        x: &[F16],
        n: usize,
        r0: usize,
        r1: usize,
        y_panel: &mut [F16],
    ) {
        let bsz = B * B;
        let mut wf = [0f32; 256]; // B * B <= 256 for B <= 16
        for (ri, r) in (r0..r1).enumerate() {
            let (lo, hi) = (p.row_ptr[r] as usize, p.row_ptr[r + 1] as usize);
            let out = &mut y_panel[ri * B * n..(ri + 1) * B * n];
            if lo == hi {
                out.fill(F16::ZERO);
                continue;
            }
            let mut j = 0;
            while j + N_TILE <= n {
                let mut acc = [[_mm256_setzero_ps(); 2]; B];
                for blk in lo..hi {
                    let c = p.cols[blk] as usize;
                    let vals = &p.values[blk * bsz..(blk + 1) * bsz];
                    for (i, chunk) in vals.chunks_exact(8).enumerate() {
                        let h = _mm_loadu_si128(chunk.as_ptr().cast::<__m128i>());
                        _mm256_storeu_ps(wf.as_mut_ptr().add(i * 8), _mm256_cvtph_ps(h));
                    }
                    for bc in 0..B {
                        let xp = x.as_ptr().add((c * B + bc) * n + j).cast::<__m128i>();
                        let x0 = _mm256_cvtph_ps(_mm_loadu_si128(xp));
                        let x1 = _mm256_cvtph_ps(_mm_loadu_si128(xp.add(1)));
                        for (br, a) in acc.iter_mut().enumerate() {
                            let w = _mm256_set1_ps(wf[br * B + bc]);
                            a[0] = _mm256_add_ps(a[0], _mm256_mul_ps(w, x0));
                            a[1] = _mm256_add_ps(a[1], _mm256_mul_ps(w, x1));
                        }
                    }
                }
                for (br, a) in acc.iter().enumerate() {
                    let op = out.as_mut_ptr().add(br * n + j).cast::<__m128i>();
                    _mm_storeu_si128(op, _mm256_cvtps_ph::<RNE>(a[0]));
                    _mm_storeu_si128(op.add(1), _mm256_cvtps_ph::<RNE>(a[1]));
                }
                j += N_TILE;
            }
            if j < n {
                spmm_tile_b::<F16, B>(p, x, n, lo, hi, j, n - j, out);
            }
        }
    }

    /// The wide twin of the scalar N:M row loop: one `N_TILE`
    /// accumulator panel as `[__m256; 2]` per output row,
    /// contributions applied as separate mul + add (no FMA) in the
    /// same (group, slot) order as the scalar body — the nibble does
    /// the column selection, lanes span only the batch axis.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn nm_rows_f32_avx2(
        p: &PreparedNm<f32>,
        x: &[f32],
        n: usize,
        r0: usize,
        r1: usize,
        y_panel: &mut [f32],
    ) {
        let groups = p.groups();
        let gb = p.group_bytes();
        for (ri, r) in (r0..r1).enumerate() {
            let out = &mut y_panel[ri * n..(ri + 1) * n];
            let mut j = 0;
            while j + N_TILE <= n {
                let mut a0 = _mm256_setzero_ps();
                let mut a1 = _mm256_setzero_ps();
                for g in 0..groups {
                    let vbase = (r * groups + g) * p.nm_n;
                    let ibase = (r * groups + g) * gb;
                    for s in 0..p.nm_n {
                        let byte = p.idx[ibase + s / 2];
                        let ci = (if s % 2 == 0 { byte & 0x0F } else { byte >> 4 }) as usize;
                        let w = _mm256_set1_ps(p.values[vbase + s]);
                        let xp = x.as_ptr().add((g * p.nm_m + ci) * n + j);
                        a0 = _mm256_add_ps(a0, _mm256_mul_ps(w, _mm256_loadu_ps(xp)));
                        a1 = _mm256_add_ps(a1, _mm256_mul_ps(w, _mm256_loadu_ps(xp.add(8))));
                    }
                }
                let op = out.as_mut_ptr().add(j);
                _mm256_storeu_ps(op, a0);
                _mm256_storeu_ps(op.add(8), a1);
                j += N_TILE;
            }
            if j < n {
                // Remainder columns run the shared scalar tile body.
                nm_tile::<f32>(p, x, n, r, j, n - j, out);
            }
        }
    }

    /// F16 storage twin: widen `x` in lanes (`vcvtph2ps`), widen each
    /// weight through the software path (one scalar — value-exact vs
    /// the hardware conversion), store through `vcvtps2ph`
    /// round-to-nearest-even.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "f16c")]
    pub(super) unsafe fn nm_rows_f16_avx2(
        p: &PreparedNm<F16>,
        x: &[F16],
        n: usize,
        r0: usize,
        r1: usize,
        y_panel: &mut [F16],
    ) {
        let groups = p.groups();
        let gb = p.group_bytes();
        for (ri, r) in (r0..r1).enumerate() {
            let out = &mut y_panel[ri * n..(ri + 1) * n];
            let mut j = 0;
            while j + N_TILE <= n {
                let mut a0 = _mm256_setzero_ps();
                let mut a1 = _mm256_setzero_ps();
                for g in 0..groups {
                    let vbase = (r * groups + g) * p.nm_n;
                    let ibase = (r * groups + g) * gb;
                    for s in 0..p.nm_n {
                        let byte = p.idx[ibase + s / 2];
                        let ci = (if s % 2 == 0 { byte & 0x0F } else { byte >> 4 }) as usize;
                        let w = _mm256_set1_ps(p.values[vbase + s].to_f32());
                        let xp = x.as_ptr().add((g * p.nm_m + ci) * n + j).cast::<__m128i>();
                        let x0 = _mm256_cvtph_ps(_mm_loadu_si128(xp));
                        let x1 = _mm256_cvtph_ps(_mm_loadu_si128(xp.add(1)));
                        a0 = _mm256_add_ps(a0, _mm256_mul_ps(w, x0));
                        a1 = _mm256_add_ps(a1, _mm256_mul_ps(w, x1));
                    }
                }
                let op = out.as_mut_ptr().add(j).cast::<__m128i>();
                _mm_storeu_si128(op, _mm256_cvtps_ph::<RNE>(a0));
                _mm_storeu_si128(op.add(1), _mm256_cvtps_ph::<RNE>(a1));
                j += N_TILE;
            }
            if j < n {
                nm_tile::<F16>(p, x, n, r, j, n - j, out);
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_f32_avx2(
        a: &[f32],
        x: &[f32],
        m: usize,
        k: usize,
        n: usize,
        y: &mut [f32],
    ) {
        let mut i0 = 0;
        while i0 < m {
            let ib = I_TILE.min(m - i0);
            let mut j = 0;
            while j + N_TILE <= n {
                let mut acc = [[_mm256_setzero_ps(); 2]; I_TILE];
                for l in 0..k {
                    let xp = x.as_ptr().add(l * n + j);
                    let x0 = _mm256_loadu_ps(xp);
                    let x1 = _mm256_loadu_ps(xp.add(8));
                    for (ii, arow) in acc.iter_mut().enumerate().take(ib) {
                        let w = _mm256_set1_ps(a[(i0 + ii) * k + l]);
                        arow[0] = _mm256_add_ps(arow[0], _mm256_mul_ps(w, x0));
                        arow[1] = _mm256_add_ps(arow[1], _mm256_mul_ps(w, x1));
                    }
                }
                for (ii, arow) in acc.iter().enumerate().take(ib) {
                    let op = y.as_mut_ptr().add((i0 + ii) * n + j);
                    _mm256_storeu_ps(op, arow[0]);
                    _mm256_storeu_ps(op.add(8), arow[1]);
                }
                j += N_TILE;
            }
            if j < n {
                dense_tile::<f32>(a, x, k, n, i0, ib, j, n - j, y);
            }
            i0 += ib;
        }
    }

    /// F16 dense twin. The per-step weight broadcast widens one
    /// scalar, so it takes the software [`F16::to_f32`] (value-exact
    /// vs `vcvtph2ps`); the streamed `x` rows widen in lanes.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "f16c")]
    pub(super) unsafe fn matmul_f16_avx2(
        a: &[F16],
        x: &[F16],
        m: usize,
        k: usize,
        n: usize,
        y: &mut [F16],
    ) {
        let mut i0 = 0;
        while i0 < m {
            let ib = I_TILE.min(m - i0);
            let mut j = 0;
            while j + N_TILE <= n {
                let mut acc = [[_mm256_setzero_ps(); 2]; I_TILE];
                for l in 0..k {
                    let xp = x.as_ptr().add(l * n + j).cast::<__m128i>();
                    let x0 = _mm256_cvtph_ps(_mm_loadu_si128(xp));
                    let x1 = _mm256_cvtph_ps(_mm_loadu_si128(xp.add(1)));
                    for (ii, arow) in acc.iter_mut().enumerate().take(ib) {
                        let w = _mm256_set1_ps(a[(i0 + ii) * k + l].to_f32());
                        arow[0] = _mm256_add_ps(arow[0], _mm256_mul_ps(w, x0));
                        arow[1] = _mm256_add_ps(arow[1], _mm256_mul_ps(w, x1));
                    }
                }
                for (ii, arow) in acc.iter().enumerate().take(ib) {
                    let op = y.as_mut_ptr().add((i0 + ii) * n + j).cast::<__m128i>();
                    _mm_storeu_si128(op, _mm256_cvtps_ph::<RNE>(arow[0]));
                    _mm_storeu_si128(op.add(1), _mm256_cvtps_ph::<RNE>(arow[1]));
                }
                j += N_TILE;
            }
            if j < n {
                dense_tile::<F16>(a, x, k, n, i0, ib, j, n - j, y);
            }
            i0 += ib;
        }
    }

    /// Dependent multiply–add chains across 8 vector accumulators:
    /// enough independent streams to saturate the FPU ports, each
    /// step a separate mul + add (no FMA) because that is the
    /// arithmetic the kernels issue — the measured peak is the
    /// ceiling *these* kernels can reach (a true FMA peak would be
    /// ~2x higher and unreachable by design).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn flops_probe_avx2(rounds: usize) -> f32 {
        let c0 = _mm256_set1_ps(0.999_999);
        let c1 = _mm256_set1_ps(1.0e-7);
        let mut acc = [_mm256_set1_ps(0.1); 8];
        for _ in 0..rounds {
            for a in acc.iter_mut() {
                *a = _mm256_add_ps(_mm256_mul_ps(*a, c0), c1);
            }
        }
        let mut buf = [0f32; 8];
        let mut total = 0f32;
        for a in acc {
            _mm256_storeu_ps(buf.as_mut_ptr(), a);
            total += buf.iter().sum::<f32>();
        }
        total
    }

    /// Streaming read over `buf` with 4 independent vector
    /// accumulators (one add per 8 floats — far below peak FLOPs, so
    /// the probe is load-bound by construction).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn bandwidth_probe_avx2(buf: &[f32]) -> f32 {
        let mut acc = [_mm256_setzero_ps(); 4];
        let chunks = buf.len() / 32;
        let p = buf.as_ptr();
        for i in 0..chunks {
            let base = p.add(i * 32);
            acc[0] = _mm256_add_ps(acc[0], _mm256_loadu_ps(base));
            acc[1] = _mm256_add_ps(acc[1], _mm256_loadu_ps(base.add(8)));
            acc[2] = _mm256_add_ps(acc[2], _mm256_loadu_ps(base.add(16)));
            acc[3] = _mm256_add_ps(acc[3], _mm256_loadu_ps(base.add(24)));
        }
        let mut buf8 = [0f32; 8];
        let mut total = 0f32;
        for a in acc {
            _mm256_storeu_ps(buf8.as_mut_ptr(), a);
            total += buf8.iter().sum::<f32>();
        }
        for &v in &buf[chunks * 32..] {
            total += v;
        }
        total
    }
}

#[cfg(target_arch = "x86_64")]
use x86::{
    matmul_f16_avx2, matmul_f32_avx2, nm_rows_f16_avx2, nm_rows_f32_avx2, spmm_rows_f16_avx2,
    spmm_rows_f32_avx2,
};

// ---------------------------------------------------------------------------
// Roofline measurement probes (tier-dispatched).
// ---------------------------------------------------------------------------

/// FLOPs one probe round performs at the AVX2 tier: 8 accumulators x
/// 8 lanes x (1 mul + 1 add).
const FLOPS_PER_ROUND_AVX2: usize = 128;

/// FLOPs one probe round performs at the scalar tier: 8 accumulators
/// x (1 mul + 1 add).
const FLOPS_PER_ROUND_SCALAR: usize = 16;

/// Run `rounds` multiply–add chain steps at the active tier's width.
/// Returns `(flops_performed, sink)` — time the call and divide to
/// get the machine's no-FMA peak; feed `sink` to
/// [`std::hint::black_box`] so the chains are not dead code.
pub fn flops_probe(rounds: usize) -> (f64, f32) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            let v = unsafe { x86::flops_probe_avx2(rounds) };
            return ((rounds * FLOPS_PER_ROUND_AVX2) as f64, v);
        }
    }
    let v = flops_probe_scalar(rounds);
    ((rounds * FLOPS_PER_ROUND_SCALAR) as f64, v)
}

fn flops_probe_scalar(rounds: usize) -> f32 {
    let (c0, c1) = (0.999_999f32, 1.0e-7f32);
    let mut acc = [0.1f32; 8];
    for _ in 0..rounds {
        for a in acc.iter_mut() {
            *a = *a * c0 + c1;
        }
    }
    acc.iter().sum()
}

/// Stream-read `buf` once at the active tier's width, returning a
/// reduction over it (feed to [`std::hint::black_box`]). Time the
/// call and divide `buf.len() * 4` bytes by it for read bandwidth.
pub fn bandwidth_probe(buf: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            return unsafe { x86::bandwidth_probe_avx2(buf) };
        }
    }
    bandwidth_probe_scalar(buf)
}

fn bandwidth_probe_scalar(buf: &[f32]) -> f32 {
    let mut acc = [0f32; 4];
    let chunks = buf.chunks_exact(4);
    let rem = chunks.remainder();
    for c in chunks {
        for (a, &v) in acc.iter_mut().zip(c) {
            *a += v;
        }
    }
    acc.iter().sum::<f32>() + rem.iter().sum::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_label_is_consistent_with_tier() {
        let label = tier_label();
        match tier() {
            SimdTier::Scalar => assert_eq!(label, "scalar"),
            SimdTier::Avx2 => assert!(label.starts_with("avx2"), "{label}"),
        }
        if f16_lanes() {
            assert_eq!(tier(), SimdTier::Avx2, "f16c without avx2 is never selected");
        }
    }

    #[test]
    fn flops_probe_reports_work_and_stays_finite() {
        let (flops, sink) = flops_probe(1000);
        assert!(flops >= 16_000.0, "at least the scalar tier's work: {flops}");
        assert!(sink.is_finite(), "chain diverged: {sink}");
        // Doubling rounds doubles reported work at any fixed tier.
        let (flops2, _) = flops_probe(2000);
        assert!((flops2 / flops - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_probe_sums_the_buffer() {
        // 1037 is deliberately not a multiple of any vector width, so
        // the tail path runs on every tier.
        let buf = vec![1.0f32; 1037];
        let total = bandwidth_probe(&buf);
        assert!((total - 1037.0).abs() < 1e-2, "{total}");
    }
}
