//! `popsparse::static_::sparseDenseMatMul` — the compile-time-pattern
//! sparse-dense matmul (paper §3.2).
//!
//! At plan time the pattern is fully known: the partitioner splits the
//! non-zero blocks over the k dimension into `q_k` *uneven* partitions
//! balancing nnz, and the dense operand over n into `q_n` partitions.
//! Values are re-ordered host-side to match the tile distribution, so
//! no weight exchange happens on device; execution is a single compute
//! superstep plus the output reduction.

pub mod partition;

use crate::error::{Error, Result};
use crate::sim::chip::{CostModel, IpuSpec};
use crate::sim::{compute, exchange, execute, Cost, MemoryPlan, Program, Superstep};
use crate::sparse::mask::BlockMask;
use crate::DType;
use partition::{balance_k_stats, KPartition, MaskStats};

/// A planned static sparse-dense matmul.
#[derive(Debug, Clone)]
pub struct StaticPlan {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub b: usize,
    pub nnz_blocks: usize,
    pub dtype: DType,
    pub q_k: usize,
    pub q_n: usize,
    /// The chosen uneven k-partitions.
    pub partitions: Vec<KPartition>,
    pub program: Program,
    pub cost: Cost,
    pub memory: MemoryPlan,
}

impl StaticPlan {
    /// Density of the planned operand.
    pub fn density(&self) -> f64 {
        (self.nnz_blocks * self.b * self.b) as f64 / (self.m as f64 * self.k as f64)
    }

    /// Achieved TFLOP/s, non-zeros only (paper §3).
    pub fn tflops(&self, spec: &IpuSpec) -> f64 {
        crate::tflops(
            crate::spmm_flops(self.m, self.k, self.n, self.density()),
            self.cost.total(),
            spec.clock_hz,
        )
    }
}

use crate::sim::chip::candidate_splits;

/// Cost one `(q_k, q_n)` candidate against precomputed partitions.
#[allow(clippy::too_many_arguments)]
fn build_program(
    mask: &BlockMask,
    parts: &[KPartition],
    n: usize,
    dtype: DType,
    q_k: usize,
    q_n: usize,
    spec: &IpuSpec,
    cm: &CostModel,
) -> Result<(Program, Cost, MemoryPlan)> {
    let tiles = q_k * q_n;
    if tiles > spec.tiles {
        return Err(Error::Plan(format!("{tiles} partitions exceed {} tiles", spec.tiles)));
    }
    let b = mask.b;
    let dsize = dtype.size();
    let tn = n.div_ceil(q_n);
    let worst = parts
        .iter()
        .max_by_key(|p| p.nnz_blocks)
        .expect("q_k >= 1 yields at least one partition")
        .clone();
    let max_kwidth = parts.iter().map(|p| p.k_width(b)).max().unwrap_or(0);

    // --- Memory -------------------------------------------------------
    // Chip level: one copy of the non-zero values + metadata, the dense
    // operand, the partial accumulators (touched rows only — static
    // mode's saving) and the output. (nnz comes from the partitions:
    // recounting the mask here is an O(mb·kb) scan per candidate.)
    let nnz_blocks_total: usize = parts.iter().map(|p| p.nnz_blocks).sum();
    let total_partial_rows: usize = parts.iter().map(|p| p.touched_block_rows * b).sum();
    let mut mem = MemoryPlan::new();
    mem.alloc("nz_values", nnz_blocks_total * b * b * dsize);
    mem.alloc("meta_info", nnz_blocks_total * 4);
    mem.alloc("x_total", mask.k() * n * dsize);
    // With q_k = 1 the accumulators ARE the output; otherwise partials
    // are reduced in bounded stages (at most one extra live copy of
    // the touched-row volume, capped by one copy of the output).
    if q_k > 1 {
        mem.alloc("partials", (total_partial_rows * n * dsize).min(mask.m() * n * dsize));
    }
    mem.alloc("y_total", mask.m() * n * dsize);
    mem.check_chip(spec)?;
    // Per tile: the partition's values/meta are resident; the partial
    // accumulator and X slab stream the batch dimension in chunks of
    // `tn_chunk` columns so the worst tile fits its SRAM. Each chunk
    // repeats the exchange/compute/reduce phase sequence.
    let fixed_bytes = worst.nnz_blocks * b * b * dsize + worst.nnz_blocks * 4 + 32 * 1024;
    let avail = spec.sram_per_tile * 9 / 10;
    if fixed_bytes >= avail {
        return Err(Error::OutOfMemory { required_bytes: fixed_bytes, available_bytes: avail });
    }
    let per_col_bytes = (worst.touched_block_rows * b + max_kwidth) * dsize;
    let tn_chunk = if per_col_bytes == 0 {
        tn
    } else {
        ((avail - fixed_bytes) / per_col_bytes).min(tn).max(1)
    };
    let n_chunks = (tn as u64).div_ceil(tn_chunk as u64);
    let mut tile_mem = MemoryPlan::new();
    tile_mem.alloc("nz_values", worst.nnz_blocks * b * b * dsize);
    tile_mem.alloc("meta_info", worst.nnz_blocks * 4);
    tile_mem.alloc("partials", worst.touched_block_rows * b * tn_chunk * dsize);
    tile_mem.alloc("x_slab", max_kwidth * tn_chunk * dsize);
    tile_mem.check(spec)?;

    // --- BSP program (repeated per n-chunk) ---------------------------
    let mut prog = Program::new(tiles);
    // 1. Dense input exchange: each tile receives the X rows of its
    //    k-range and n-chunk. Weight values were pre-placed host-side
    //    (static mode's key saving: no weight exchange, Fig 1 a.1).
    prog.push(
        Superstep::exchange("x-exchange", exchange::slab_bytes(max_kwidth, tn_chunk, dsize))
            .repeated(n_chunks),
    );
    // 2. On-tile sparse matmul over the balanced nnz.
    let macs = (worst.nnz_blocks * b * b) as u64 * tn_chunk as u64;
    prog.push(
        Superstep::compute(
            "spmm",
            compute::sparse_matmul_cycles(
                macs,
                worst.nnz_blocks as u64,
                b,
                tn_chunk as u64,
                dtype,
                spec,
                cm,
            ),
        )
        .repeated(n_chunks),
    );
    // 3. Reduce partials across the q_k partitions (Fig 1 a.2). Static
    //    mode only exchanges rows that were actually touched.
    if q_k > 1 {
        // Reduction spread over the q_k tiles of each n-group: each
        // receives its share of every other tile's touched rows.
        let per_tile_elems = (total_partial_rows as u64 * tn_chunk as u64).div_ceil(q_k as u64);
        let bytes = per_tile_elems * (q_k as u64 - 1) / (q_k as u64) * dsize as u64;
        let adds = per_tile_elems;
        prog.push(
            Superstep::mixed("reduce", compute::reduce_cycles(adds, cm), bytes)
                .repeated(n_chunks),
        );
    }
    let cost = execute(&prog, spec);
    Ok((prog, cost, mem))
}

/// Plan a static sparse-dense matmul for a known pattern.
pub fn plan(
    mask: &BlockMask,
    n: usize,
    dtype: DType,
    spec: &IpuSpec,
    cm: &CostModel,
) -> Result<StaticPlan> {
    if n == 0 {
        return Err(Error::Plan("zero batch".into()));
    }
    if mask.nnz_blocks() == 0 {
        return Err(Error::Plan("empty sparsity pattern".into()));
    }
    let mut best: Option<StaticPlan> = None;
    let mut last_oom = None;
    // One O(mb*kb) scan of the mask; every candidate below reuses it.
    let stats = MaskStats::of(mask);
    for &q_k in &candidate_splits(mask.kb, spec.tiles) {
        // Partitions depend only on q_k: compute once per q_k.
        let partitions = balance_k_stats(&stats, q_k);
        for &q_n in &candidate_splits(n, spec.tiles / q_k) {
            match build_program(mask, &partitions, n, dtype, q_k, q_n, spec, cm) {
                Ok((program, cost, memory)) => {
                    let better =
                        best.as_ref().map(|p| cost.total() < p.cost.total()).unwrap_or(true);
                    if better {
                        best = Some(StaticPlan {
                            m: mask.m(),
                            k: mask.k(),
                            n,
                            b: mask.b,
                            nnz_blocks: mask.nnz_blocks(),
                            dtype,
                            q_k,
                            q_n,
                            partitions: partitions.clone(),
                            program,
                            cost,
                            memory,
                        });
                    }
                }
                Err(e @ Error::OutOfMemory { .. }) => last_oom = Some(e),
                Err(_) => {}
            }
        }
    }
    best.ok_or_else(|| last_oom.unwrap_or_else(|| Error::Plan("no feasible static plan".into())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::patterns;

    fn env() -> (IpuSpec, CostModel) {
        (IpuSpec::default(), CostModel::default())
    }

    fn paper_mask(b: usize, inv_d: usize) -> BlockMask {
        patterns::with_density(4096, 4096, b, 1.0 / inv_d as f64, 42).unwrap()
    }

    #[test]
    fn beats_dense_at_paper_config() {
        // Table 3: m=k=4096, d=1/16, b=16, FP16 → static/dense ≈ 4.9.
        let (spec, cm) = env();
        let mask = paper_mask(16, 16);
        let n = 8192;
        let sp = plan(&mask, n, DType::Fp16, &spec, &cm).unwrap();
        let dn = crate::dense_::plan(4096, 4096, n, DType::Fp16, &spec, &cm).unwrap();
        let speedup = dn.cost.total() as f64 / sp.cost.total() as f64;
        assert!((2.0..9.0).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn unstructured_slower_than_dense_at_d16() {
        // Table 3: b=1, FP16, d=1/16 → static/dense ≈ 0.7 (< 1).
        let (spec, cm) = env();
        let mask = paper_mask(1, 16);
        let n = 8192;
        let sp = plan(&mask, n, DType::Fp16, &spec, &cm).unwrap();
        let dn = crate::dense_::plan(4096, 4096, n, DType::Fp16, &spec, &cm).unwrap();
        let speedup = dn.cost.total() as f64 / sp.cost.total() as f64;
        assert!(speedup < 1.5, "b=1 speedup {speedup} should be near or below 1");
    }

    #[test]
    fn block_size_monotone() {
        let (spec, cm) = env();
        let n = 4096;
        let mut last = f64::MAX;
        for b in [1usize, 4, 8, 16] {
            let mask = paper_mask(b, 16);
            let p = plan(&mask, n, DType::Fp16, &spec, &cm).unwrap();
            let cyc = p.cost.total() as f64;
            assert!(cyc < last, "b={b} must be faster than smaller blocks");
            last = cyc;
        }
    }

    #[test]
    fn fp32_speedup_exceeds_fp16() {
        // §5.2: FLOP savings count more in FP32.
        let (spec, cm) = env();
        let mask = paper_mask(16, 16);
        let n = 4096;
        let ratio = |dt| {
            let sp = plan(&mask, n, dt, &spec, &cm).unwrap();
            let dn = crate::dense_::plan(4096, 4096, n, dt, &spec, &cm).unwrap();
            dn.cost.total() as f64 / sp.cost.total() as f64
        };
        assert!(ratio(DType::Fp32) > ratio(DType::Fp16));
    }

    #[test]
    fn rejects_empty_and_zero_batch() {
        let (spec, cm) = env();
        let empty = BlockMask::zeros(64, 64, 16).unwrap();
        assert!(plan(&empty, 64, DType::Fp16, &spec, &cm).is_err());
        let mask = patterns::uniform(64, 64, 16, 4, 0).unwrap();
        assert!(plan(&mask, 0, DType::Fp16, &spec, &cm).is_err());
    }

    #[test]
    fn plan_metadata_consistent() {
        let (spec, cm) = env();
        let mask = patterns::uniform(512, 512, 8, 300, 9).unwrap();
        let p = plan(&mask, 256, DType::Fp32, &spec, &cm).unwrap();
        assert_eq!(p.partitions.len(), p.q_k);
        assert_eq!(p.nnz_blocks, 300);
        assert!(p.q_k * p.q_n <= spec.tiles);
        assert!(p.tflops(&spec) > 0.0);
    }
}
