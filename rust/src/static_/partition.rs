//! Static-mode partitioning: nnz-balanced, *uneven* splits of the k
//! dimension (paper §3.2 / Fig. 1a).
//!
//! Because the sparsity pattern is known at compile time, the
//! partitioner can place cut points so every partition holds (nearly)
//! the same number of non-zero blocks — the property that removes
//! dynamic mode's overflow/propagation machinery entirely.

use crate::sparse::mask::BlockMask;

/// One k-partition: a half-open block-column range and its contents.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KPartition {
    /// Block-column range `[c0, c1)`.
    pub c0: usize,
    pub c1: usize,
    /// Non-zero blocks inside the range.
    pub nnz_blocks: usize,
    /// Distinct block rows touched (determines reduction volume).
    pub touched_block_rows: usize,
}

impl KPartition {
    /// Width in elements.
    pub fn k_width(&self, b: usize) -> usize {
        (self.c1 - self.c0) * b
    }
}

/// Precomputed mask statistics shared across partition candidates —
/// the planner evaluates many `q_k` values against one mask, so the
/// O(mb·kb) scans happen once, not per candidate.
#[derive(Debug, Clone)]
pub struct MaskStats {
    pub mb: usize,
    pub kb: usize,
    /// Non-zero blocks per block column.
    pub col_counts: Vec<usize>,
    /// All non-zero coordinates, (row, col) sorted.
    pub coords: Vec<(usize, usize)>,
}

impl MaskStats {
    pub fn of(mask: &BlockMask) -> Self {
        // Single row-major pass for both statistics (mask.col_counts()
        // alone walks the grid column-major — a cache-hostile stride).
        let mut col_counts = vec![0usize; mask.kb];
        let mut coords = Vec::with_capacity(mask.nnz_blocks());
        for r in 0..mask.mb {
            for c in 0..mask.kb {
                if mask.get(r, c) {
                    col_counts[c] += 1;
                    coords.push((r, c));
                }
            }
        }
        Self { mb: mask.mb, kb: mask.kb, col_counts, coords }
    }
}

/// Split the mask's block columns into `q_k` contiguous ranges with
/// balanced non-zero block counts (greedy over the column prefix sum).
pub fn balance_k(mask: &BlockMask, q_k: usize) -> Vec<KPartition> {
    balance_k_stats(&MaskStats::of(mask), q_k)
}

/// [`balance_k`] against precomputed [`MaskStats`] (one O(nnz) pass).
pub fn balance_k_stats(stats: &MaskStats, q_k: usize) -> Vec<KPartition> {
    assert!(q_k >= 1);
    let total: usize = stats.col_counts.iter().sum();
    // Cannot split finer than block columns; extra partitions idle.
    let eff_q_k = q_k.min(stats.kb);

    // 1. Choose cut points greedily on the column prefix sum.
    let mut cuts = Vec::with_capacity(eff_q_k + 1); // partition boundaries
    cuts.push(0);
    let mut acc = 0usize;
    let mut assigned = 0usize;
    for c in 0..stats.kb {
        acc += stats.col_counts[c];
        let remaining_parts = eff_q_k - (cuts.len() - 1);
        let remaining_cols = stats.kb - (c + 1);
        // Close the partition when we reach the running target, or when
        // we must leave one column per remaining partition.
        let target = (total - assigned) as f64 / remaining_parts as f64;
        let close = remaining_parts > 1
            && (acc as f64 >= target || remaining_cols < remaining_parts - 1);
        if close || c == stats.kb - 1 {
            cuts.push(c + 1);
            assigned += acc;
            acc = 0;
            if cuts.len() == eff_q_k + 1 {
                break;
            }
        }
    }
    if *cuts.last().expect("cuts always starts with 0") != stats.kb {
        cuts.push(stats.kb);
    }

    // 2. One pass over the coordinates: count nnz and touched rows per
    //    partition (coords are row-sorted, so "touched" is a run test).
    //    A direct column→partition lookup table replaces a per-coord
    //    binary search (§Perf: 3-4x on unstructured b=1 planning).
    let nparts = cuts.len() - 1;
    let mut col_part = vec![0u32; stats.kb];
    for p in 0..nparts {
        for c in cuts[p]..cuts[p + 1] {
            col_part[c] = p as u32;
        }
    }
    let mut nnz = vec![0usize; nparts];
    let mut touched = vec![0usize; nparts];
    let mut last_row_seen = vec![usize::MAX; nparts];
    for &(r, c) in &stats.coords {
        let p = col_part[c] as usize;
        nnz[p] += 1;
        if last_row_seen[p] != r {
            last_row_seen[p] = r;
            touched[p] += 1;
        }
    }

    let mut parts: Vec<KPartition> = (0..nparts)
        .map(|p| KPartition {
            c0: cuts[p],
            c1: cuts[p + 1],
            nnz_blocks: nnz[p],
            touched_block_rows: touched[p],
        })
        .collect();
    // If columns ran out before q_k partitions, pad with empty ranges
    // so callers can rely on the length (those tiles simply idle).
    while parts.len() < q_k {
        parts.push(KPartition { c0: stats.kb, c1: stats.kb, nnz_blocks: 0, touched_block_rows: 0 });
    }
    parts
}

/// Largest partition nnz divided by the ideal (1.0 = perfectly even).
pub fn imbalance(parts: &[KPartition]) -> f64 {
    let total: usize = parts.iter().map(|p| p.nnz_blocks).sum();
    if total == 0 {
        return 1.0;
    }
    let max = parts.iter().map(|p| p.nnz_blocks).max().unwrap_or(0);
    max as f64 * parts.len() as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::patterns;

    #[test]
    fn covers_all_columns_disjointly() {
        let mask = patterns::uniform(512, 512, 16, 100, 3).unwrap();
        let parts = balance_k(&mask, 8);
        assert_eq!(parts.len(), 8);
        assert_eq!(parts[0].c0, 0);
        for w in parts.windows(2) {
            assert_eq!(w[0].c1, w[1].c0, "ranges must be contiguous");
        }
        assert_eq!(parts.last().unwrap().c1, mask.kb);
        let total: usize = parts.iter().map(|p| p.nnz_blocks).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn balances_uniform_patterns_well() {
        let mask = patterns::uniform(2048, 2048, 16, 2048, 5).unwrap();
        let parts = balance_k(&mask, 16);
        assert!(imbalance(&parts) < 1.3, "imbalance {}", imbalance(&parts));
    }

    #[test]
    fn adapts_to_skewed_patterns() {
        // All nnz in the left quarter of the columns: static cuts must
        // concentrate there, keeping balance far better than even splits.
        let mask = patterns::corner_packed(1024, 1024, 16, 256).unwrap();
        let parts = balance_k(&mask, 8);
        assert!(imbalance(&parts) < 1.6, "imbalance {}", imbalance(&parts));
        // Even (dynamic-style) splits would put everything in the first
        // one or two partitions: imbalance ≈ q_k.
    }

    #[test]
    fn q_k_larger_than_columns() {
        let mask = patterns::uniform(64, 64, 16, 6, 1).unwrap(); // kb = 4
        let parts = balance_k(&mask, 8);
        assert_eq!(parts.len(), 8);
        let nnz: usize = parts.iter().map(|p| p.nnz_blocks).sum();
        assert_eq!(nnz, 6);
        // padded partitions are empty
        assert_eq!(parts[7].nnz_blocks, 0);
    }

    #[test]
    fn single_partition() {
        let mask = patterns::uniform(128, 128, 16, 10, 2).unwrap();
        let parts = balance_k(&mask, 1);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].nnz_blocks, 10);
        assert_eq!((parts[0].c0, parts[0].c1), (0, mask.kb));
    }

    #[test]
    fn touched_rows_counted() {
        let mask = crate::sparse::BlockMask::from_coords(
            64,
            64,
            16,
            &[(0, 0), (1, 0), (1, 1), (3, 3)],
        )
        .unwrap();
        let parts = balance_k(&mask, 2);
        // cols {0} touch rows {0,1}; cols {1..4} touch rows {1,3}: row 1
        // produces partials in both partitions.
        assert_eq!(parts.iter().map(|p| p.touched_block_rows).sum::<usize>(), 4);
    }
}
